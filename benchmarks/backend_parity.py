"""Backend parity + microbench: RWMA vs BWMA through the *actual kernels*.

Earlier benchmarks compared the pure-jnp blockwise operators against
row-major XLA — a layout comparison, not an execution one.  This section
runs the full blocked encoder through each registered execution backend
("reference" = jnp blockwise, "pallas" = the Pallas BWMA kernels, interpret
mode off-TPU) and reports wall time plus max abs error against the row-major
baseline, so the paper's RWMA-vs-BWMA claim is finally measured on the
kernel path it describes.

The paged-decode section does the same for the serving hot loop: the fused
paged-attention kernels (dense/GQA and MLA) and the COW page copy are swept
per page count against the jnp gather->attend oracle they replace, emitting
wall time per backend and max abs error (the BWMA-table format).  Attention
errors stay within online-softmax reassociation (<= 1e-6); the page copy is
bit-exact.

Note on CPU numbers: interpret mode executes the kernel body per grid step
in Python — its wall time is a correctness/dispatch-overhead signal, not a
performance claim.  On TPU the same BlockSpecs compile natively.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.core import encoder as enc
from repro.core.backend import BACKENDS, resolve_backend


def run(scale: float = 1.0, block: int = 128):
    print("# backend parity: blocked encoder through each execution backend")
    seq = max(128, int(512 * min(scale, 1.0)))
    cfg = enc.EncoderConfig(
        seq_len=seq, d_model=768, n_heads=12, d_head=64, d_ff=3072,
        n_layers=1, block=block,
    )
    params = enc.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (cfg.seq_len, cfg.d_model))
    bp = enc.block_params(params, cfg)

    y_rwma, us_rwma = timed(lambda: np.asarray(enc.encoder_rwma(params, x, cfg)))
    emit("backend/rwma_jnp/us", us_rwma, f"seq={seq} block={block}")

    for name in sorted(BACKENDS):
        y, us = timed(
            lambda name=name: np.asarray(enc.encoder_bwma(bp, x, cfg, backend=name))
        )
        err = float(np.abs(y - y_rwma).max())
        emit(f"backend/{name}/us", us, f"max_abs_err_vs_rwma={err:.2e}")

    run_paged(scale)


def _paged_layout(rng, B, maxp, page, leaf_shapes):
    """A serving-shaped paged layout: per-slot table rows of distinct
    physical pages (page 0 reserved as the null page) + random pools."""
    num_pages = B * maxp + 1
    table = np.zeros((B, maxp), np.int32)
    phys = rng.permutation(np.arange(1, num_pages))
    for b in range(B):
        table[b] = phys[b * maxp:(b + 1) * maxp]
    pools = [
        jnp.asarray(rng.standard_normal((num_pages,) + s), jnp.float32)
        for s in leaf_shapes
    ]
    return jnp.asarray(table), pools


def run_paged(scale: float = 1.0, page: int = 8):
    """Per-page-count sweep: fused paged-decode kernels vs the gather oracle.

    Each row doubles the slots' mapped history (seq_pos fills every mapped
    page), so the reference gather bytes grow linearly while the kernel
    streams the same pages tile-by-tile.
    """
    print("# paged decode: fused kernels vs jnp gather oracle per page count")
    B, H, hkv, dh = 2, 8, 4, 32
    r, dr = 32, 16
    scale_mla = (r + dr) ** -0.5
    ref, pal = resolve_backend("reference"), resolve_backend("pallas")
    # one jitted callable per (backend, op); each maxp is a fresh shape and
    # traces once into the same cache
    f_gqa = {
        "reference": jax.jit(ref.paged_attention_decode),
        "pallas": jax.jit(pal.paged_attention_decode),
    }
    f_mla = {
        "reference": jax.jit(
            lambda *a: ref.mla_paged_attention_decode(*a, scale=scale_mla)),
        "pallas": jax.jit(
            lambda *a: pal.mla_paged_attention_decode(*a, scale=scale_mla)),
    }
    for maxp in (1, 2, 4, 8):
        rng = np.random.default_rng(maxp)
        seq_pos = jnp.full((B,), maxp * page - 1, jnp.int32)
        # dense/GQA
        table, (k_pages, v_pages) = _paged_layout(
            rng, B, maxp, page, [(page, hkv, dh)] * 2
        )
        q = jnp.asarray(rng.standard_normal((B, 1, H, dh)), jnp.float32)
        y_ref, us_ref = timed(lambda: np.asarray(
            f_gqa["reference"](q, k_pages, v_pages, table, seq_pos)))
        y_pal, us_pal = timed(lambda: np.asarray(
            f_gqa["pallas"](q, k_pages, v_pages, table, seq_pos)))
        err = float(np.abs(y_pal - y_ref).max())
        gathered = 2 * B * maxp * page * hkv * dh * 4  # ref K+V HBM bytes
        emit(f"paged/gqa_p{maxp}/reference_us", us_ref,
             f"gather_bytes={gathered}")
        emit(f"paged/gqa_p{maxp}/pallas_us", us_pal,
             f"max_abs_err_vs_reference={err:.2e}")
        # MLA (absorbed latent scoring)
        table, (ckv_pages, kr_pages) = _paged_layout(
            rng, B, maxp, page, [(page, r), (page, dr)]
        )
        q_lat = jnp.asarray(rng.standard_normal((B, 1, H, r)), jnp.float32)
        q_rope = jnp.asarray(rng.standard_normal((B, 1, H, dr)), jnp.float32)
        y_ref, us_ref = timed(lambda: np.asarray(
            f_mla["reference"](q_lat, q_rope, ckv_pages, kr_pages, table,
                               seq_pos)))
        y_pal, us_pal = timed(lambda: np.asarray(
            f_mla["pallas"](q_lat, q_rope, ckv_pages, kr_pages, table,
                            seq_pos)))
        err = float(np.abs(y_pal - y_ref).max())
        emit(f"paged/mla_p{maxp}/reference_us", us_ref, "")
        emit(f"paged/mla_p{maxp}/pallas_us", us_pal,
             f"max_abs_err_vs_reference={err:.2e}")
    # COW page copy (page-count independent: one page moves)
    rng = np.random.default_rng(0)
    pool = {"k_pages": jnp.asarray(
        rng.standard_normal((4, 9, page, hkv, dh)), jnp.float32)}
    f_ref = jax.jit(ref.paged_copy_page)
    f_pal = jax.jit(pal.paged_copy_page)
    y_ref, us_ref = timed(lambda: np.asarray(
        f_ref(pool, jnp.int32(1), jnp.int32(2))["k_pages"]))
    y_pal, us_pal = timed(lambda: np.asarray(
        f_pal(pool, jnp.int32(1), jnp.int32(2))["k_pages"]))
    exact = bool(np.array_equal(y_pal, y_ref))
    emit("paged/cow_copy/reference_us", us_ref, "")
    emit("paged/cow_copy/pallas_us", us_pal, f"bit_exact={exact}")


if __name__ == "__main__":
    run()

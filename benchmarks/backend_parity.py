"""Backend parity + microbench: RWMA vs BWMA through the *actual kernels*.

Earlier benchmarks compared the pure-jnp blockwise operators against
row-major XLA — a layout comparison, not an execution one.  This section
runs the full blocked encoder through each registered execution backend
("reference" = jnp blockwise, "pallas" = the Pallas BWMA kernels, interpret
mode off-TPU) and reports wall time plus max abs error against the row-major
baseline, so the paper's RWMA-vs-BWMA claim is finally measured on the
kernel path it describes.

Note on CPU numbers: interpret mode executes the kernel body per grid step
in Python — its wall time is a correctness/dispatch-overhead signal, not a
performance claim.  On TPU the same BlockSpecs compile natively.
"""
import jax
import numpy as np

from benchmarks.common import emit, timed
from repro.core import encoder as enc
from repro.core.backend import BACKENDS


def run(scale: float = 1.0, block: int = 128):
    print("# backend parity: blocked encoder through each execution backend")
    seq = max(128, int(512 * min(scale, 1.0)))
    cfg = enc.EncoderConfig(
        seq_len=seq, d_model=768, n_heads=12, d_head=64, d_ff=3072,
        n_layers=1, block=block,
    )
    params = enc.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (cfg.seq_len, cfg.d_model))
    bp = enc.block_params(params, cfg)

    y_rwma, us_rwma = timed(lambda: np.asarray(enc.encoder_rwma(params, x, cfg)))
    emit("backend/rwma_jnp/us", us_rwma, f"seq={seq} block={block}")

    for name in sorted(BACKENDS):
        y, us = timed(
            lambda name=name: np.asarray(enc.encoder_bwma(bp, x, cfg, backend=name))
        )
        err = float(np.abs(y - y_rwma).max())
        emit(f"backend/{name}/us", us, f"max_abs_err_vs_rwma={err:.2e}")


if __name__ == "__main__":
    run()

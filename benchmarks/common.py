"""Shared benchmark helpers: CSV emit + paper constants."""
import time

CPU_HZ = 2.3e9  # paper §4.1: 2.3 GHz


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)


def cycles_to_ms(cycles: int) -> float:
    return cycles / CPU_HZ * 1e3


def timed(fn, *args, repeat=3, **kw):
    fn(*args, **kw)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) / repeat * 1e6

"""Paper §3.2: RWMA<->BWMA conversion cost vs whole-model run-time (~0.1%)."""
from benchmarks.common import emit
from repro.core import memmodel as mm


def run(scale: float = 1.0):
    wl = mm.WorkloadConfig() if scale >= 1.0 else mm.WorkloadConfig(
        seq=int(512 * scale), d_ff=int(3072 * scale)
    )
    print("# conversion overhead (12-layer model)")
    for accel in mm.PAPER_ACCELERATORS:
        frac = mm.conversion_overhead_fraction(wl, accel, n_layers=12)
        emit(f"conversion/{accel.name}", 0.0,
             f"{frac*100:.3f}% (paper: ~0.1%)")


if __name__ == "__main__":
    run()

"""Paper Fig. 6a: BERT-base layer execution time, RWMA vs BWMA, per
accelerator (SA8x8, SA16x16, SIMD16), single core."""
from benchmarks.common import cycles_to_ms, emit
from repro.core import memmodel as mm


def run(scale: float = 1.0):
    wl = mm.WorkloadConfig() if scale >= 1.0 else mm.WorkloadConfig(
        seq=int(512 * scale), d_ff=int(3072 * scale)
    )
    print("# fig6a: BERT layer exec time (ms @2.3GHz), single core")
    for accel in mm.PAPER_ACCELERATORS:
        r = mm.simulate_layer(wl, accel, "rwma")["total"].cycles
        b = mm.simulate_layer(wl, accel, "bwma")["total"].cycles
        emit(f"fig6a/{accel.name}/rwma_ms", cycles_to_ms(r) * 1e3,
             f"cycles={r}")
        emit(f"fig6a/{accel.name}/bwma_ms", cycles_to_ms(b) * 1e3,
             f"cycles={b}")
        emit(f"fig6a/{accel.name}/speedup", 0.0, f"{r / b:.2f}x")


if __name__ == "__main__":
    run()

"""Paper Fig. 6b: multi-core scaling (1/2/4 cores, SA16x16 per core)."""
from benchmarks.common import cycles_to_ms, emit
from repro.core import memmodel as mm


def run(scale: float = 1.0):
    wl = mm.WorkloadConfig() if scale >= 1.0 else mm.WorkloadConfig(
        seq=int(512 * scale), d_ff=int(3072 * scale)
    )
    accel = mm.AccelSpec.sa(16)
    print("# fig6b: multi-core (SA16x16/core), ms @2.3GHz")
    results = {}
    for cores in (1, 2, 4):
        r = mm.simulate_layer(wl, accel, "rwma", cores)["total"].cycles
        b = mm.simulate_layer(wl, accel, "bwma", cores)["total"].cycles
        results[cores] = (r, b)
        emit(f"fig6b/cores{cores}/rwma_ms", cycles_to_ms(r) * 1e3, "")
        emit(f"fig6b/cores{cores}/bwma_ms", cycles_to_ms(b) * 1e3,
             f"speedup={r/b:.2f}x")
    # paper headline: single-core BWMA beats dual-core RWMA
    emit(
        "fig6b/bwma1core_vs_rwma2core", 0.0,
        f"{'PASS' if results[1][1] < results[2][0] else 'FAIL'} "
        f"({cycles_to_ms(results[1][1]):.0f}ms vs {cycles_to_ms(results[2][0]):.0f}ms)",
    )


if __name__ == "__main__":
    run()

"""Paper Fig. 7: execution-time distribution, GEMM vs non-GEMM components."""
from benchmarks.common import emit
from repro.core import memmodel as mm


def run(scale: float = 1.0):
    wl = mm.WorkloadConfig() if scale >= 1.0 else mm.WorkloadConfig(
        seq=int(512 * scale), d_ff=int(3072 * scale)
    )
    accel = mm.AccelSpec.sa(16)
    print("# fig7: component shares (SA16x16, single core)")
    for layout in ("rwma", "bwma"):
        res = mm.simulate_layer(wl, accel, layout)
        total = res["total"].cycles
        gemm = sum(res[c].cycles for c in mm.GEMM_COMPONENTS)
        ng = sum(res[c].cycles for c in mm.NON_GEMM_COMPONENTS)
        emit(f"fig7/{layout}/gemm_share", 0.0, f"{gemm/total*100:.1f}%")
        emit(f"fig7/{layout}/non_gemm_share", 0.0, f"{ng/total*100:.1f}%")
        for c in mm.GEMM_COMPONENTS + mm.NON_GEMM_COMPONENTS:
            emit(f"fig7/{layout}/{c}", 0.0,
                 f"{res[c].cycles/total*100:.1f}%")
    # paper: RWMA non-GEMM 4.2%, BWMA 13.5%


if __name__ == "__main__":
    run()

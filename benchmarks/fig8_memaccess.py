"""Paper Fig. 8: memory accesses / misses per hierarchy level."""
from benchmarks.common import emit
from repro.core import memmodel as mm


def run(scale: float = 1.0):
    wl = mm.WorkloadConfig() if scale >= 1.0 else mm.WorkloadConfig(
        seq=int(512 * scale), d_ff=int(3072 * scale)
    )
    accel = mm.AccelSpec.sa(16)
    print("# fig8: memory hierarchy accesses (SA16x16, single core)")
    stats = {}
    for layout in ("rwma", "bwma"):
        t = mm.simulate_layer(wl, accel, layout)["total"]
        stats[layout] = t
        emit(f"fig8/{layout}/l1_accesses", 0.0, str(t.l1_accesses))
        emit(f"fig8/{layout}/l1_misses", 0.0, str(t.l1_misses))
        emit(f"fig8/{layout}/l2_accesses", 0.0, str(t.l2_accesses))
        emit(f"fig8/{layout}/l2_misses", 0.0, str(t.l2_misses))
        emit(f"fig8/{layout}/dram_accesses", 0.0, str(t.dram_accesses))
    r, b = stats["rwma"], stats["bwma"]
    emit("fig8/l1_miss_ratio_rwma_over_bwma", 0.0,
         f"{r.l1_misses/max(b.l1_misses,1):.1f}x (paper: 12.3x)")
    emit("fig8/l2_access_ratio", 0.0,
         f"{r.l2_accesses/max(b.l2_accesses,1):.1f}x")


if __name__ == "__main__":
    run()

"""TPU-adaptation evidence: Pallas kernel DMA contiguity + VMEM report.

No real TPU here, so instead of wall-time we report the *structural*
quantities that govern TPU performance and that the BWMA layout changes:
per-grid-step DMA descriptor count (contiguous runs the BlockSpec fetch
decomposes into), bytes per descriptor, and VMEM working set — plus a
wall-clock microbench of the pure-jnp blocked ops (XLA:CPU) as a sanity
signal.
"""
import jax
import numpy as np

from benchmarks.common import emit, timed
from repro.core import blockwise as bw
from repro.core.backend import resolve_backend
from repro.core.layout import BlockLayout


def dma_descriptors(block_shape, array_shape, esize=2):
    """How many contiguous HBM runs one BlockSpec step touches.

    For a trailing-dims-contiguous block (BWMA 4-D layout) this is 1; for a
    2-D row-major operand it is the number of non-contiguous row segments.
    """
    # RWMA (bm, bk) block of (M, K): bm separated row segments.  BWMA
    # (1,1,bm,bk) of (gm, gk, bm, bk): trailing dims contiguous, 1 segment.
    if len(block_shape) == 2:
        return block_shape[0]
    return 1


def run(scale: float = 1.0, backend: str = "reference"):
    print("# kernel report: DMA contiguity + VMEM per BlockSpec step")
    bm = bk = bn = 128
    M = K = 1024
    esize = 2  # bf16
    rwma_desc = dma_descriptors((bm, bk), (M, K))
    bwma_desc = dma_descriptors((1, 1, bm, bk), (M // bm, K // bk, bm, bk))
    emit("kernel/rwma_gemm/dma_descriptors_per_step", 0.0, str(rwma_desc))
    emit("kernel/bwma_gemm/dma_descriptors_per_step", 0.0, str(bwma_desc))
    emit("kernel/descriptor_reduction", 0.0, f"{rwma_desc/bwma_desc:.0f}x")
    emit("kernel/bytes_per_descriptor_rwma", 0.0, f"{bk*esize}")
    emit("kernel/bytes_per_descriptor_bwma", 0.0, f"{bm*bk*esize}")
    vmem = (bm * bk + bk * bn + bm * bn) * 4  # f32 accum
    emit("kernel/vmem_working_set_bytes", 0.0,
         f"{vmem} ({vmem/2**20:.2f} MiB of ~16 MiB)")

    # paged-decode structural numbers: per grid step the fused decode kernel
    # fetches exactly one physical K/V page — a trailing-dims-contiguous
    # (1, page, hkv, dh) tile, one DMA descriptor — while the reference path
    # first materializes every slot's gathered history in HBM
    # (max_pages * page tokens per slot, K and V).  Serving-shaped numbers
    # (page=128 tokens, 8 KV heads, d_head=128, bf16):
    page_tok, hkv, dh = 128, 8, 128
    maxp = 8  # 1k-token history
    tile = page_tok * hkv * dh * esize
    gathered = 2 * maxp * tile  # K+V, per slot per layer per decode step
    emit("kernel/paged_decode/dma_descriptors_per_step", 0.0, "1")
    emit("kernel/paged_decode/bytes_per_descriptor", 0.0, f"{tile}")
    emit("kernel/paged_decode/reference_gather_bytes", 0.0,
         f"{gathered} (per slot/layer/step; kernel streams, never lands)")
    vmem_paged = (2 * page_tok * hkv * dh + 2 * page_tok) * 4
    emit("kernel/paged_decode/vmem_working_set_bytes", 0.0,
         f"{vmem_paged} ({vmem_paged/2**20:.2f} MiB of ~16 MiB)")

    # blocked GEMM wall time through the selected execution backend
    # ("reference" = pure-jnp on XLA:CPU; "pallas" = the BWMA kernels,
    # interpret mode off-TPU — a dispatch/correctness signal there).
    be = resolve_backend(backend)
    lo = BlockLayout(128, 128)
    m = int(512 * max(scale, 0.25))
    a = jax.random.normal(jax.random.PRNGKey(0), (m, 768))
    b = jax.random.normal(jax.random.PRNGKey(1), (768, 768))
    ab, bb = bw.block(a, lo), bw.block(b, lo)
    f_b = jax.jit(lambda x, y: be.matmul(x, y).data)
    _, us_b = timed(lambda: np.asarray(f_b(ab, bb)))
    f_r = jax.jit(lambda x, y: x @ y)
    _, us_r = timed(lambda: np.asarray(f_r(a, b)))
    emit(f"kernel/bw_matmul_{be.name}", us_b, f"rwma_jnp={us_r:.0f}us")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--backend", default="reference",
                    help="execution backend: reference | pallas")
    args = ap.parse_args()
    run(scale=args.scale, backend=args.backend)

"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus # section headers).

  fig6a — exec time per accelerator (paper Fig. 6a)
  fig6b — multi-core scaling (paper Fig. 6b)
  fig7  — GEMM vs non-GEMM breakdown (paper Fig. 7)
  fig8  — memory accesses per level (paper Fig. 8)
  conversion — RWMA<->BWMA conversion overhead (paper §3.2)
  kernel_report — Pallas DMA-contiguity / VMEM structure (TPU adaptation)
  backend_parity — blocked encoder through each execution backend
  roofline — summary of dry-run roofline terms, if artifacts exist
"""
import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0,
                    help="<1.0 shrinks the memmodel workload (CI speed)")
    ap.add_argument("--only", nargs="*", default=None)
    args, _ = ap.parse_known_args()

    from benchmarks import (
        backend_parity,
        conversion_overhead,
        fig6a_accelerators,
        fig6b_cores,
        fig7_breakdown,
        fig8_memaccess,
        kernel_report,
        serve_throughput,
    )

    sections = {
        "fig6a": fig6a_accelerators.run,
        "fig6b": fig6b_cores.run,
        "fig7": fig7_breakdown.run,
        "fig8": fig8_memaccess.run,
        "conversion": conversion_overhead.run,
        "kernel_report": kernel_report.run,
        "backend_parity": backend_parity.run,
        "serve_throughput": serve_throughput.run,
    }
    for name, fn in sections.items():
        if args.only and name not in args.only:
            continue
        fn(scale=args.scale)

    # roofline summary (reads dry-run artifacts when present)
    if (args.only is None or "roofline" in args.only) and os.path.isdir(
        "experiments/dryrun"
    ):
        from repro.analysis import roofline as R

        recs = R.load_all("experiments/dryrun")
        rows = [a for a in (R.analyze_record(r) for r in recs) if a]
        print(f"# roofline: {len(rows)} compiled cells")
        for r in sorted(rows, key=lambda x: x["roofline_fraction"]):
            print(
                f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},0.0,"
                f"dom={r['dominant']} frac={r['roofline_fraction']:.3f} "
                f"useful={r['useful_ratio']:.2f}"
            )


if __name__ == "__main__":
    main()

"""Continuous batching vs static-wave serving throughput.

A staggered-arrival workload (Poisson-ish gaps, per-request generation
lengths, all from one fixed seed) is driven through both engines:

* **static-wave** — the pre-paging ``Server``: waves of ``max_seqs``
  requests decode in lockstep for the wave's longest generation, finished
  slots burning steps on padding;
* **continuous** — the ``Engine`` over the block-paged KV cache: a finished
  request's slot and pages are re-filled from the queue the same step.

Both run the workload once cold (compile) and once warm (timed).  Reported:
warm tokens/s, decode slot-step efficiency (useful tokens / slot-steps
executed), and greedy-output parity between the engines.  Continuous
batching must come out >= the static wave on tokens/s — that is the
repo-level acceptance gate for the serving subsystem.

Usage:  PYTHONPATH=src:. python benchmarks/serve_throughput.py [--arch ...]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from benchmarks.common import emit
from repro.models import model as M
from repro.serve import (
    Engine,
    EngineConfig,
    ServeConfig,
    Server,
    make_requests,
    run_static_waves,
)


def _run_static(cfg, params, reqs, args, max_len):
    srv = Server(cfg, params, ServeConfig(max_len=max_len, seed=args.seed))
    t0 = time.perf_counter()
    outs = run_static_waves(srv, reqs, args.max_seqs)
    wall = time.perf_counter() - t0
    # slot-steps: every wave burns its longest generation length in every slot
    slot_steps = 0
    order = sorted(reqs, key=lambda r: (r["arrival_step"], r["rid"]))
    for w in range(0, len(order), args.max_seqs):
        wave = order[w : w + args.max_seqs]
        slot_steps += len(wave) * max(r["max_new_tokens"] for r in wave)
    return outs, wall, slot_steps


def _run_continuous(cfg, params, reqs, args, max_len):
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=args.max_seqs, max_len=max_len,
        page_size=args.page_size, seed=args.seed,
    ))
    for r in reqs:
        eng.submit(r["prompt"], r["max_new_tokens"],
                   rid=r["rid"], arrival_step=r["arrival_step"])
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    outs = {r.rid: np.asarray(r.out_tokens, np.int32) for r in done}
    stats = {
        "slot_steps": eng.decode_steps * args.max_seqs,
        "queue_steps": [r.stats.queue_steps for r in done],
        "preemptions": sum(r.stats.n_preemptions for r in done),
        "page_size": eng.kv.page_size,
        "cache_mb": eng.kv.cache_bytes() / 1e6,
    }
    return outs, wall, stats


def run(scale: float = 1.0, argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--num-requests", type=int, default=16)
    ap.add_argument("--max-seqs", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--mean-interarrival", type=float, default=3.0)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    args, _ = ap.parse_known_args(argv)

    print("# serve throughput: continuous batching vs static waves "
          f"(arch={args.arch}, {args.num_requests} requests, "
          f"max_seqs={args.max_seqs})")
    # benchmark shape: the smoke config scaled to where a decode step is
    # real device work — at smoke size (2L, d=96) the host-side scheduling
    # overhead swamps the compute and wall-clock measures noise, not the
    # engines.  ~4L/d=256 keeps compile < 10 s on CPU.
    cfg = C.get_config(args.arch, smoke=True, dtype=jnp.float32)
    if cfg.family == "dense" and scale >= 0.5:
        import dataclasses
        cfg = dataclasses.replace(
            cfg, n_layers=4, d_model=256, n_heads=8, n_kv_heads=8,
            d_head=32, d_ff=512,
        )
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reqs = make_requests(
        cfg.vocab_size, args.num_requests,
        prompt_len=args.prompt_len, max_new=args.max_new,
        mean_interarrival=args.mean_interarrival, seed=args.seed,
    )
    useful = sum(r["max_new_tokens"] for r in reqs)
    max_len = args.prompt_len + args.max_new + 1

    # cold pass compiles every jit cache both engines need; then time
    # ``repeats`` back-to-back (static, continuous) PAIRS and take the
    # median of per-pair ratios — load bursts on a shared CI runner hit
    # both halves of a pair about equally, so the ratio is far more stable
    # than two independently-timed walls
    st_out, _, st_slot_steps = _run_static(cfg, params, reqs, args, max_len)
    ct_out, _, ct = _run_continuous(cfg, params, reqs, args, max_len)
    st_wall = ct_wall = float("inf")
    ratios = []
    for _ in range(args.repeats):
        _, sw, _ = _run_static(cfg, params, reqs, args, max_len)
        _, cw, _ = _run_continuous(cfg, params, reqs, args, max_len)
        st_wall, ct_wall = min(st_wall, sw), min(ct_wall, cw)
        ratios.append(sw / cw)

    st_tps = useful / st_wall
    ct_tps = useful / ct_wall
    emit("serve/static_wave/tok_s", st_tps,
         f"slot_steps={st_slot_steps} "
         f"efficiency={useful / st_slot_steps:.2f}")
    emit("serve/continuous/tok_s", ct_tps,
         f"slot_steps={ct['slot_steps']} "
         f"efficiency={useful / ct['slot_steps']:.2f} "
         f"preemptions={ct['preemptions']} page={ct['page_size']} "
         f"cache_mb={ct['cache_mb']:.2f}")

    match = all(
        np.array_equal(st_out[r["rid"]], ct_out[r["rid"]]) for r in reqs
    )
    speedup = sorted(ratios)[len(ratios) // 2]  # median of paired ratios
    emit("serve/continuous_vs_static/speedup", speedup,
         f"outputs_match={match} pair_ratios="
         + "/".join(f"{r:.2f}" for r in sorted(ratios)))
    print(f"# continuous {ct_tps:.1f} tok/s vs static {st_tps:.1f} tok/s, "
          f"median paired speedup {speedup:.2f}x, "
          f"greedy outputs match: {match}")
    if not match:
        # at this (threaded-matmul) shape the two engines prefill at
        # different batch shapes, so XLA CPU may partition the contraction
        # differently and a near-tie argmax can flip — the bitwise parity
        # guarantee is asserted in tests/test_serve.py at thread-stable
        # shapes; here a mismatch is reported, not fatal
        print("# note: divergence is a near-tie argmax flip under threaded "
              "XLA CPU matmul, see tests/test_serve.py for the parity gate")
    return speedup, ct["slot_steps"], st_slot_steps


if __name__ == "__main__":
    # standalone (CI) gates; the benchmarks.run harness only reports.
    # slot-steps are deterministic — that comparison is hard.  wall clock
    # on a shared runner is not, so the paired-median ratio only fails on a
    # clear regression; typical measured margin is 1.2-2.2x.
    speedup, ct_steps, st_steps = run()
    if ct_steps > st_steps:
        raise SystemExit(
            f"continuous used more decode slot-steps ({ct_steps}) than "
            f"static waves ({st_steps})"
        )
    if speedup < 0.85:
        raise SystemExit(
            f"continuous batching clearly slower than static waves "
            f"({speedup:.2f}x median paired)"
        )

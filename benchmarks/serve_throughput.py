"""Continuous batching vs static-wave serving throughput.

A staggered-arrival workload (Poisson-ish gaps, per-request generation
lengths, all from one fixed seed) is driven through both engines:

* **static-wave** — the pre-paging ``Server``: waves of ``max_seqs``
  requests decode in lockstep for the wave's longest generation, finished
  slots burning steps on padding;
* **continuous** — the ``Engine`` over the block-paged KV cache: a finished
  request's slot and pages are re-filled from the queue the same step.

Both run the workload once cold (compile) and once warm (timed).  Reported:
warm tokens/s, decode slot-step efficiency (useful tokens / slot-steps
executed), and greedy-output parity between the engines.  Continuous
batching must come out >= the static wave on tokens/s — that is the
repo-level acceptance gate for the serving subsystem.

``--long-prompt`` switches to the **chunked-admission gate**: a max-length
prompt arrives while short requests are decoding, and the benchmark
measures the longest stall (max wall-clock engine-step time) the in-flight
decodes suffer during that admission — once with chunked prefill (the
default engine) and once with one-shot prefill.  Chunked admission must cut
the worst-case stall: that is the repo-level acceptance gate for chunked
prefill (tests/test_serve.py gates the same property deterministically in
step units; this gate shows it in wall-clock).

``--shared-prefix`` switches to the **prefix-sharing gate**: N requests
share an 8-page prompt prefix (the shared-system-prompt traffic shape) and
run once with the shared-prefix page cache and once without.  Sharing must
cut mean TTFT in engine steps (deterministic — later admissions alias the
cached prefix and chunk-prefill only their suffix) and allocate fewer
pool pages (the prefix is stored once, not once per request): those are
the repo-level acceptance gates for shared-prefix serving.  Outputs must
match between the two runs bit for bit.

``--backend pallas`` runs the continuous engine through the fused
paged-attention / COW kernels (interpret mode off-TPU) instead of the jnp
gather oracle; the static baseline always serves through the reference
path, so the parity check doubles as an engine-level backend gate.

``--mesh DxM`` switches to the **tensor-parallel gate**: the same workload
runs through the continuous engine once single-device and once sharded
over a ``(data, model)`` mesh (simulate on CPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``).  The gates are
deterministic, not wall-clock: the sharded run's greedy tokens must be
bit-identical to the single-device run, and when the family's KV pool
head-shards, the per-device pool bytes must be exactly ``total / TP`` —
the memory claim tensor parallelism exists to deliver.  (Wall-clock does
not improve on a simulated mesh: every "device" is a slice of one CPU.)

Every mode also merges its results (ratios, TTFT, tok/s, pool stats) into
the ``BENCH_serve.json`` artifact (``--bench-out``; keyed ``mode:arch``,
with ``:pallas`` appended for non-reference backends and ``:mesh=DxM``
for sharded runs so every variant coexists) — the machine-readable perf
trajectory CI uploads per run.

Usage:  PYTHONPATH=src:. python benchmarks/serve_throughput.py [--arch ...]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from benchmarks.common import emit
from repro.models import model as M
from repro.serve import (
    Engine,
    EngineConfig,
    ServeConfig,
    Server,
    make_requests,
    run_static_waves,
)


def _write_bench(args, mode: str, payload: dict) -> None:
    """Merge one gate's results into the BENCH_serve.json perf artifact.

    Keyed ``mode:arch`` so the three gates (and per-family runs) coexist in
    one file; an existing artifact is updated in place, so a CI job running
    several gates uploads a single trajectory document.
    """
    if not args.bench_out:
        return
    try:
        with open(args.bench_out, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError):
        doc = {}
    # the backend is a real result dimension: a pallas run coexists with the
    # reference run under its own key instead of overwriting it
    key = f"{mode}:{args.arch}"
    if args.backend != "reference":
        key += f":{args.backend}"
    if args.mesh:
        key += f":mesh={args.mesh}"
    doc[key] = {"backend": args.backend, "mesh": args.mesh, **payload}
    with open(args.bench_out, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    print(f"# bench artifact [{key}] -> {args.bench_out}")


def _run_static(cfg, params, reqs, args, max_len):
    # bucket by the page size (not cfg.block): the throughput comparison
    # should measure scheduling, not hand the static baseline extra pad work
    srv = Server(cfg, params, ServeConfig(
        max_len=max_len, seed=args.seed, prefill_bucket=args.page_size,
    ))
    t0 = time.perf_counter()
    outs = run_static_waves(srv, reqs, args.max_seqs)
    wall = time.perf_counter() - t0
    # slot-steps: every wave burns its longest generation length in every slot
    slot_steps = 0
    order = sorted(reqs, key=lambda r: (r["arrival_step"], r["rid"]))
    for w in range(0, len(order), args.max_seqs):
        wave = order[w : w + args.max_seqs]
        slot_steps += len(wave) * max(r["max_new_tokens"] for r in wave)
    return outs, wall, slot_steps


def _run_continuous(cfg, params, reqs, args, max_len, mesh=None):
    # chunk granularity trades admission latency for dispatch overhead: the
    # throughput gate uses a few pages per chunk (vLLM-style budget) so the
    # comparison measures scheduling, not per-chunk fixed costs at smoke
    # scale; the --long-prompt gate keeps page-granular chunks for the
    # sharpest decode interleave
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=args.max_seqs, max_len=max_len,
        page_size=args.page_size, seed=args.seed,
        prefill_chunk=args.prefill_chunk, backend=args.backend,
    ), mesh=mesh)
    for r in reqs:
        eng.submit(r["prompt"], r["max_new_tokens"],
                   rid=r["rid"], arrival_step=r["arrival_step"])
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    outs = {r.rid: np.asarray(r.out_tokens, np.int32) for r in done}
    stats = {
        "slot_steps": eng.decode_steps * args.max_seqs,
        "queue_steps": [r.stats.queue_steps for r in done],
        "ttft_steps": [r.stats.ttft_steps for r in done],
        "preemptions": sum(r.stats.n_preemptions for r in done),
        "page_size": eng.kv.page_size,
        "cache_mb": eng.kv.cache_bytes() / 1e6,
        "cache_bytes": eng.kv.cache_bytes(),
        "cache_bytes_per_device": eng.kv.cache_bytes_per_device(),
        "pool": eng.kv.pool_stats(),
    }
    return outs, wall, stats


# one arch per cache-adapter family, so `--family` can point the gate at
# any adapter the registry serves (ISSUE: the CI gate covers MLA too)
FAMILY_ARCHS = {
    "dense": "minicpm-2b",
    "swa": "h2o-danube-3-4b",
    "ssm": "mamba2-130m",
    "hybrid": "hymba-1.5b",
    "mla": "deepseek-v3-671b",
    "encdec": "whisper-tiny",
}


def _scaled_cfg(args, scale):
    # benchmark shape: the smoke config scaled to where a decode step is
    # real device work — at smoke size (2L, d=96) the host-side scheduling
    # overhead swamps the compute and wall-clock measures noise, not the
    # engines.  ~4L/d=256 keeps compile < 10 s on CPU.
    cfg = C.get_config(args.arch, smoke=True, dtype=jnp.float32)
    import dataclasses
    if cfg.family == "dense" and scale >= 0.5:  # repro: noqa RPR004 -- bench sizing table, not a dispatch path
        cfg = dataclasses.replace(
            cfg, n_layers=4, d_model=256, n_heads=8, n_kv_heads=8,
            d_head=32, d_ff=512,
        )
    elif cfg.attn_type == "mla" and scale >= 0.5:  # repro: noqa RPR004 -- bench sizing table, not a dispatch path
        cfg = dataclasses.replace(
            cfg, n_layers=4, d_model=256, n_heads=8, n_kv_heads=8,
            q_lora_rank=96, kv_lora_rank=64, qk_nope_dim=32, qk_rope_dim=16,
            v_head_dim=32, d_ff=512, moe_d_ff=128, first_k_dense=1,
        )
    return cfg


def _long_prompt_trial(cfg, params, args, chunked: bool):
    """One long-prompt admission against an in-flight decode batch.

    Returns (max engine-step wall time while the long prompt was being
    admitted, the long request's TTFT in steps, outputs).  Each step syncs
    the device so step walls measure compute, not dispatch.
    """
    max_len = args.long_prompt_len + args.max_new + 1
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=args.max_seqs, max_len=max_len, page_size=args.page_size,
        chunked_prefill=chunked, prefill_tokens_per_step=args.page_size,
        seed=args.seed, backend=args.backend,
    ))
    rng = np.random.default_rng(args.seed)
    victims = [
        eng.submit(
            rng.integers(0, cfg.vocab_size, size=(args.prompt_len,)).astype(np.int32),
            args.max_new, rid=i, arrival_step=0,
        )
        for i in range(args.max_seqs - 1)
    ]
    long_req = eng.submit(
        rng.integers(0, cfg.vocab_size, size=(args.long_prompt_len,)).astype(np.int32),
        4, rid=args.max_seqs - 1, arrival_step=2,
    )
    walls = []
    while eng.sched.has_work():
        t0 = time.perf_counter()
        eng.step()
        jax.block_until_ready(eng.kv.data)
        walls.append(time.perf_counter() - t0)
        if eng.step_count > 10_000:
            raise RuntimeError("engine did not drain")
    eng._flush_pending()
    s = long_req.stats
    window = walls[s.admitted_step : s.first_token_step + 1]
    outs = {r.rid: list(r.out_tokens) for r in victims + [long_req]}
    return max(window), s.first_token_step - s.admitted_step, outs


def run_long_prompt(scale: float, args) -> float:
    print("# serve long-prompt admission: chunked vs one-shot prefill "
          f"(arch={args.arch}, long={args.long_prompt_len} tokens, "
          f"{args.max_seqs - 1} in-flight decodes)")
    cfg = _scaled_cfg(args, scale)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    # cold pass (compile), then paired trials for a load-robust ratio
    _long_prompt_trial(cfg, params, args, chunked=True)
    _long_prompt_trial(cfg, params, args, chunked=False)
    ratios, ch_stall = [], float("inf")
    un_stall = float("inf")
    match = True
    for _ in range(args.repeats):
        c_stall, c_ttft, c_out = _long_prompt_trial(cfg, params, args, True)
        u_stall, u_ttft, u_out = _long_prompt_trial(cfg, params, args, False)
        ch_stall, un_stall = min(ch_stall, c_stall), min(un_stall, u_stall)
        ratios.append(c_stall / u_stall)
        match = match and c_out == u_out
    ratio = sorted(ratios)[len(ratios) // 2]
    emit("serve/long_prompt/chunked_max_stall_ms", ch_stall * 1e3,
         f"ttft_steps={c_ttft}")
    emit("serve/long_prompt/oneshot_max_stall_ms", un_stall * 1e3,
         f"ttft_steps={u_ttft}")
    emit("serve/long_prompt/stall_ratio", ratio,
         f"outputs_match={match} pair_ratios="
         + "/".join(f"{r:.2f}" for r in sorted(ratios)))
    print(f"# in-flight decode max stall during admission: chunked "
          f"{ch_stall * 1e3:.1f} ms vs one-shot {un_stall * 1e3:.1f} ms "
          f"(median paired ratio {ratio:.2f}, outputs match: {match})")
    _write_bench(args, "long_prompt", {
        "chunked_max_stall_ms": ch_stall * 1e3,
        "oneshot_max_stall_ms": un_stall * 1e3,
        "stall_ratio_median": ratio,
        "pair_ratios": sorted(ratios),
        "chunked_ttft_steps": c_ttft,
        "oneshot_ttft_steps": u_ttft,
        "outputs_match": match,
    })
    return ratio


def _shared_prefix_trial(cfg, params, args, sharing: bool):
    """One shared-system-prompt workload through the engine.

    Returns (mean TTFT in engine steps — deterministic scheduling units,
    not wall clock, pages allocated from the pool, cached prompt tokens,
    outputs).  The first ``max_seqs`` admissions land before any prefix is
    published and miss; every later admission aliases the shared pages.
    """
    prefix_tokens = args.shared_prefix_pages * args.page_size
    max_len = prefix_tokens + args.prompt_len + args.max_new + 1
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=args.max_seqs, max_len=max_len, page_size=args.page_size,
        seed=args.seed, prefix_sharing=sharing, backend=args.backend,
    ))
    rng = np.random.default_rng(args.seed)
    prefix = rng.integers(0, cfg.vocab_size, size=(prefix_tokens,))
    reqs = []
    for i in range(args.num_requests):
        suffix = rng.integers(0, cfg.vocab_size, size=(args.prompt_len,))
        prompt = np.concatenate([prefix, suffix]).astype(np.int32)
        reqs.append(eng.submit(prompt, args.max_new, rid=i, arrival_step=0))
    done = eng.run()
    ttft = [r.stats.ttft_steps for r in done]
    outs = {r.rid: list(r.out_tokens) for r in done}
    return (
        float(np.mean(ttft)),
        eng.kv.allocator.pages_allocated,
        sum(r.stats.cached_prompt_tokens for r in done),
        outs,
    )


def run_shared_prefix(scale: float, args):
    """The prefix-sharing gate: shared page cache vs cold-per-request."""
    prefix_tokens = args.shared_prefix_pages * args.page_size
    print("# serve shared-prefix: prefix page cache vs per-request prefill "
          f"(arch={args.arch}, {args.num_requests} requests sharing "
          f"{args.shared_prefix_pages} pages = {prefix_tokens} tokens)")
    cfg = _scaled_cfg(args, scale)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    sh_ttft, sh_pages, sh_cached, sh_out = _shared_prefix_trial(
        cfg, params, args, sharing=True
    )
    un_ttft, un_pages, _un_cached, un_out = _shared_prefix_trial(
        cfg, params, args, sharing=False
    )
    match = sh_out == un_out
    saved = un_pages - sh_pages
    emit("serve/shared_prefix/shared_ttft_steps", sh_ttft,
         f"pages_allocated={sh_pages} cached_tokens={sh_cached}")
    emit("serve/shared_prefix/unshared_ttft_steps", un_ttft,
         f"pages_allocated={un_pages}")
    emit("serve/shared_prefix/pages_saved", saved,
         f"outputs_match={match}")
    print(f"# mean TTFT {sh_ttft:.1f} steps shared vs {un_ttft:.1f} unshared, "
          f"{saved} pool pages saved ({sh_pages} vs {un_pages} allocated), "
          f"outputs match: {match}")
    _write_bench(args, "shared_prefix", {
        "shared_ttft_steps": sh_ttft,
        "unshared_ttft_steps": un_ttft,
        "pages_saved": saved,
        "pages_allocated": {"shared": sh_pages, "unshared": un_pages},
        "cached_tokens": sh_cached,
        "outputs_match": match,
    })
    return sh_ttft, un_ttft, saved, match


def run_mesh(scale: float, args):
    """The tensor-parallel gate: sharded engine vs single-device parity.

    Both gates are deterministic, so the smoke shape is the right one: the
    scaled threaded-matmul shape can flip a near-tie argmax between
    batchings, and wall-clock says nothing on a simulated mesh (every
    "device" is a slice of one CPU).  What must hold exactly: bit-identical
    greedy tokens, and per-device pool bytes == total / TP whenever the
    family's KV pool head-shards on the model axis (MLA latent pools
    replicate by design and must stay byte-identical per device).
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed import sharding as SH
    from repro.launch.mesh import make_serve_mesh

    mesh = make_serve_mesh(args.mesh)
    tp = mesh.shape["model"]
    print("# serve mesh: tensor-parallel continuous engine vs single-device "
          f"(arch={args.arch}, mesh={args.mesh}, backend={args.backend}, "
          f"{args.num_requests} requests)")
    cfg = C.get_config(args.arch, smoke=True, dtype=jnp.float32)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reqs = make_requests(
        cfg.vocab_size, args.num_requests,
        prompt_len=args.prompt_len, max_new=args.max_new,
        mean_interarrival=args.mean_interarrival, seed=args.seed,
    )
    max_len = args.prompt_len + args.max_new + 1
    base_out, _, _base = _run_continuous(cfg, params, reqs, args, max_len)
    mesh_out, _, sharded = _run_continuous(
        cfg, params, reqs, args, max_len, mesh=mesh
    )
    match = all(
        np.array_equal(base_out[r["rid"]], mesh_out[r["rid"]]) for r in reqs
    )
    total = sharded["cache_bytes"]
    per_dev = sharded["cache_bytes_per_device"]
    # expectation from the adapter registry's own specs: does this family's
    # pool carry the model axis at all?
    pools = jax.eval_shape(lambda: M.init_paged_cache(
        cfg, args.max_seqs, 1, args.page_size, max_len
    ))
    specs = jax.tree.leaves(
        SH.paged_cache_pspecs(cfg, mesh, pools),
        is_leaf=lambda x: isinstance(x, P),
    )
    head_sharded = any("model" in tuple(s) for s in specs)
    expect = total // tp if head_sharded else total
    emit("serve/mesh/parity", float(match), f"mesh={args.mesh} tp={tp}")
    emit("serve/mesh/pool_bytes_per_device", per_dev,
         f"total={total} expected={expect} head_sharded={head_sharded}")
    print(f"# sharded greedy parity: {match}; pool {total} B total -> "
          f"{per_dev} B/device (expected {expect}, tp={tp}, "
          f"head-sharded={head_sharded})")
    _write_bench(args, "mesh", {
        "outputs_match": match,
        "tp": tp,
        "pool_bytes_total": total,
        "pool_bytes_per_device": per_dev,
        "pool_bytes_per_device_expected": expect,
        "pool_head_sharded": head_sharded,
        "slot_steps": sharded["slot_steps"],
        "preemptions": sharded["preemptions"],
        "page_size": sharded["page_size"],
    })
    return match, per_dev, expect


def run(scale: float = 1.0, argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--family", choices=sorted(FAMILY_ARCHS),
                    help="pick the arch by cache-adapter family instead of "
                         "--arch (one representative per adapter)")
    ap.add_argument("--num-requests", type=int, default=16)
    ap.add_argument("--max-seqs", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--mean-interarrival", type=float, default=3.0)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prefill chunk tokens for the throughput run "
                         "(0 derives one page)")
    ap.add_argument("--backend", default="reference",
                    choices=("reference", "pallas"),
                    help="paged-decode path for the continuous engine: the "
                         "jnp gather oracle or the fused paged-attention / "
                         "COW kernels (compiled on TPU, interpret mode "
                         "elsewhere).  Recorded in the bench artifact")
    ap.add_argument("--mesh", default="",
                    help="DxM mesh spec (e.g. 1x2): run the tensor-parallel "
                         "gate instead — sharded-vs-single-device greedy "
                         "parity + per-device pool bytes.  Needs D*M visible "
                         "devices (simulate with XLA_FLAGS=--xla_force_host_"
                         "platform_device_count=N)")
    ap.add_argument("--long-prompt", action="store_true",
                    help="run the chunked-admission stall gate instead")
    ap.add_argument("--long-prompt-len", type=int, default=512)
    ap.add_argument("--shared-prefix", action="store_true",
                    help="run the prefix-sharing gate instead: N requests "
                         "sharing a multi-page prompt prefix, cache vs cold")
    ap.add_argument("--shared-prefix-pages", type=int, default=8,
                    help="pages of shared prompt prefix for --shared-prefix")
    ap.add_argument("--bench-out", default="BENCH_serve.json",
                    help="merge this run's results (keyed mode:arch) into "
                         "this JSON perf artifact ('' disables)")
    args, _ = ap.parse_known_args(argv)
    if args.repeats < 1:
        ap.error("--repeats must be >= 1")
    if args.family:
        args.arch = FAMILY_ARCHS[args.family]

    if args.mesh:
        return run_mesh(scale, args), None, "mesh"
    if args.long_prompt:
        return run_long_prompt(scale, args), None, None
    if args.shared_prefix:
        return run_shared_prefix(scale, args), None, "shared-prefix"

    print("# serve throughput: continuous batching vs static waves "
          f"(arch={args.arch}, backend={args.backend}, "
          f"{args.num_requests} requests, max_seqs={args.max_seqs})")
    cfg = _scaled_cfg(args, scale)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reqs = make_requests(
        cfg.vocab_size, args.num_requests,
        prompt_len=args.prompt_len, max_new=args.max_new,
        mean_interarrival=args.mean_interarrival, seed=args.seed,
    )
    useful = sum(r["max_new_tokens"] for r in reqs)
    max_len = args.prompt_len + args.max_new + 1

    # cold pass compiles every jit cache both engines need; then time
    # ``repeats`` back-to-back (static, continuous) PAIRS and take the
    # median of per-pair ratios — load bursts on a shared CI runner hit
    # both halves of a pair about equally, so the ratio is far more stable
    # than two independently-timed walls
    st_out, _, st_slot_steps = _run_static(cfg, params, reqs, args, max_len)
    ct_out, _, ct = _run_continuous(cfg, params, reqs, args, max_len)
    st_wall = ct_wall = float("inf")
    ratios = []
    for _ in range(args.repeats):
        _, sw, _ = _run_static(cfg, params, reqs, args, max_len)
        _, cw, _ = _run_continuous(cfg, params, reqs, args, max_len)
        st_wall, ct_wall = min(st_wall, sw), min(ct_wall, cw)
        ratios.append(sw / cw)

    st_tps = useful / st_wall
    ct_tps = useful / ct_wall
    emit("serve/static_wave/tok_s", st_tps,
         f"slot_steps={st_slot_steps} "
         f"efficiency={useful / st_slot_steps:.2f}")
    emit("serve/continuous/tok_s", ct_tps,
         f"slot_steps={ct['slot_steps']} "
         f"efficiency={useful / ct['slot_steps']:.2f} "
         f"preemptions={ct['preemptions']} page={ct['page_size']} "
         f"cache_mb={ct['cache_mb']:.2f}")

    match = all(
        np.array_equal(st_out[r["rid"]], ct_out[r["rid"]]) for r in reqs
    )
    speedup = sorted(ratios)[len(ratios) // 2]  # median of paired ratios
    emit("serve/continuous_vs_static/speedup", speedup,
         f"outputs_match={match} pair_ratios="
         + "/".join(f"{r:.2f}" for r in sorted(ratios)))
    print(f"# continuous {ct_tps:.1f} tok/s vs static {st_tps:.1f} tok/s, "
          f"median paired speedup {speedup:.2f}x, "
          f"greedy outputs match: {match}")
    _write_bench(args, "throughput", {
        "speedup_median": speedup,
        "pair_ratios": sorted(ratios),
        "static_tok_s": st_tps,
        "continuous_tok_s": ct_tps,
        "slot_steps": {"static": st_slot_steps,
                       "continuous": ct["slot_steps"]},
        "efficiency": {"static": useful / st_slot_steps,
                       "continuous": useful / ct["slot_steps"]},
        "queue_steps": ct["queue_steps"],
        "ttft_steps": ct["ttft_steps"],
        "preemptions": ct["preemptions"],
        "page_size": ct["page_size"],
        "cache_mb": ct["cache_mb"],
        "pool": ct["pool"],
        "outputs_match": match,
    })
    if not match:
        # at this (threaded-matmul) shape the two engines prefill at
        # different batch shapes, so XLA CPU may partition the contraction
        # differently and a near-tie argmax can flip; MoE archs additionally
        # regroup the capacity dispatch when prompts batch/chunk differently
        # — the bitwise parity guarantee is asserted in tests/test_serve.py
        # at thread-stable, dispatch-stable shapes; here a mismatch is
        # reported, not fatal
        print("# note: divergence is a near-tie argmax flip under threaded "
              "XLA CPU matmul (MoE: capacity-dispatch regrouping), see "
              "tests/test_serve.py for the parity gate")
    return speedup, ct["slot_steps"], st_slot_steps


if __name__ == "__main__":
    # standalone (CI) gates; the benchmarks.run harness only reports.
    # slot-steps are deterministic — that comparison is hard.  wall clock
    # on a shared runner is not, so the paired-median ratio only fails on a
    # clear regression; typical measured margin is 1.2-2.2x.
    speedup, ct_steps, st_steps = run()
    if st_steps == "mesh":
        # deterministic, so both gates are hard: the sharded engine must
        # reproduce the single-device greedy stream bit for bit, and the
        # per-device pool bytes must match the registry's sharding specs
        # (total/TP when head-sharded, total when replicated).
        match, per_dev, expect = speedup
        if not match:
            raise SystemExit(
                "sharded greedy outputs diverged from single-device"
            )
        if per_dev != expect:
            raise SystemExit(
                f"per-device pool bytes {per_dev} != expected {expect}"
            )
        raise SystemExit(0)
    if st_steps == "shared-prefix":
        # deterministic step/page accounting, so the gates are hard: the
        # shared run must admit later requests to their first token sooner
        # (mean TTFT in engine steps) AND allocate fewer pool pages, with
        # greedy outputs bit-identical between the two runs.
        sh_ttft, un_ttft, saved, match = speedup
        if not match:
            # the bitwise guarantee is gated in tests/test_serve.py at
            # thread-stable shapes; at this scaled shape the shared and
            # unshared runs prefill at different chunk counts, where
            # threaded XLA CPU matmul can flip a near-tie argmax — report,
            # don't fail (same policy as the throughput parity note)
            print("# note: output divergence at scaled shape — see the "
                  "parity gates in tests/test_serve.py")
        if not sh_ttft < un_ttft:
            raise SystemExit(
                f"prefix sharing did not cut mean TTFT "
                f"({sh_ttft:.1f} vs {un_ttft:.1f} engine steps unshared)"
            )
        if not saved > 0:
            raise SystemExit(
                f"prefix sharing saved no pool pages (saved={saved})"
            )
        raise SystemExit(0)
    if ct_steps is None:
        # --long-prompt mode: `speedup` is the chunked/one-shot stall ratio.
        # chunked admission must clearly cut the in-flight decode's worst
        # stall; at the default shape the measured ratio is ~0.1-0.4.
        if speedup > 0.8:
            raise SystemExit(
                f"chunked prefill did not reduce the decode stall during a "
                f"long-prompt admission ({speedup:.2f}x of one-shot)"
            )
        raise SystemExit(0)
    if ct_steps > st_steps:
        raise SystemExit(
            f"continuous used more decode slot-steps ({ct_steps}) than "
            f"static waves ({st_steps})"
        )
    if speedup < 0.85:
        raise SystemExit(
            f"continuous batching clearly slower than static waves "
            f"({speedup:.2f}x median paired)"
        )

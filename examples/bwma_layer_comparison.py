"""Reproduce the paper's headline figures on the simulated SoC, end to end.

Prints the Fig. 6a / 6b / 7 / 8 quantities for the full BERT-base layer —
the numbers EXPERIMENTS.md cites.  (~2-3 min: the SA8x8 trace is large.)

Run:  PYTHONPATH=src:. python examples/bwma_layer_comparison.py [--fast]
"""
import argparse

from repro.core import memmodel as mm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced workload (seconds instead of minutes)")
    args = ap.parse_args()
    wl = (mm.WorkloadConfig(seq=128, d_model=192, n_heads=3, d_head=64,
                            d_ff=768)
          if args.fast else mm.WorkloadConfig())
    print(f"workload: BERT layer seq={wl.seq} d={wl.d_model} "
          f"heads={wl.n_heads} ff={wl.d_ff}")
    for accel in mm.PAPER_ACCELERATORS:
        r = mm.simulate_layer(wl, accel, "rwma")["total"]
        b = mm.simulate_layer(wl, accel, "bwma")["total"]
        print(f"{accel.name:8s}  RWMA {r.cycles/2.3e6:8.1f} ms   "
              f"BWMA {b.cycles/2.3e6:8.1f} ms   speedup {r.cycles/b.cycles:.2f}x"
              f"   L1-miss ratio {r.l1_misses/max(b.l1_misses,1):.1f}x")
    accel = mm.AccelSpec.sa(16)
    for cores in (1, 2, 4):
        r = mm.simulate_layer(wl, accel, "rwma", cores)["total"].cycles
        b = mm.simulate_layer(wl, accel, "bwma", cores)["total"].cycles
        print(f"cores={cores}  RWMA {r/2.3e6:8.1f} ms  BWMA {b/2.3e6:8.1f} ms")


if __name__ == "__main__":
    main()

"""Quickstart: the paper's technique end-to-end in 60 lines.

1. Build a BERT-base-style encoder (the paper's case study).
2. Run it in conventional row-major (RWMA) and block-wise (BWMA) layout —
   numerically identical, layout-only change.
3. Show the memory-hierarchy consequence on the paper's simulated SoC:
   same math, ~2-3x fewer cycles under BWMA.

Run:  PYTHONPATH=src:. python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import encoder as enc
from repro.core import memmodel as mm
from repro.core.layout import BlockLayout, to_blockwise
from repro.kernels.bwma_gemm import bwma_gemm

# --- 1. a (reduced) paper model -------------------------------------------
cfg = enc.EncoderConfig(seq_len=128, d_model=192, n_heads=3, d_head=64,
                        d_ff=768, n_layers=2, block=16)
params = enc.init_params(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (cfg.seq_len, cfg.d_model))

# --- 2. run both memory arrangements --------------------------------------
y_rwma = enc.encoder_rwma(params, x, cfg)
y_bwma = enc.encoder_bwma(enc.block_params(params, cfg), x, cfg)
print("max |BWMA - RWMA| =", float(jnp.abs(y_bwma - y_rwma).max()),
      "(layout is purely a memory-system concern)")

# --- 3. the Pallas kernel view (TPU target, interpret on CPU) -------------
lo = BlockLayout(16, 16)
a = jax.random.normal(jax.random.PRNGKey(2), (64, 96))
b = jax.random.normal(jax.random.PRNGKey(3), (96, 48))
out = bwma_gemm(to_blockwise(a, lo), to_blockwise(b, lo), interpret=True)
print("bwma_gemm grid ran:", out.shape, "— each grid step fetched ONE "
      "contiguous block from (simulated) HBM")

# --- 4. why it is faster: the paper's measurement --------------------------
wl = mm.WorkloadConfig(seq=cfg.seq_len, d_model=cfg.d_model,
                       n_heads=cfg.n_heads, d_head=cfg.d_head, d_ff=cfg.d_ff)
accel = mm.AccelSpec.sa(16)
r = mm.simulate_layer(wl, accel, "rwma")["total"]
bw_ = mm.simulate_layer(wl, accel, "bwma")["total"]
print(f"simulated SoC (32KB L1 / 1MB L2, SA16x16): "
      f"RWMA {r.cycles:,} cycles vs BWMA {bw_.cycles:,} cycles "
      f"-> {r.cycles / bw_.cycles:.2f}x speedup")
print(f"L1 misses: {r.l1_misses:,} -> {bw_.l1_misses:,} "
      f"({r.l1_misses / max(bw_.l1_misses, 1):.1f}x fewer)")

"""Batched serving example: prefill + decode across three cache families.

Shows the per-family cache behaviour the serving engine manages:
  * minicpm (dense MHA)      — full KV cache,
  * h2o-danube (SWA)         — O(window) ring buffer,
  * mamba2 (SSM)             — O(1) state.

Run:  PYTHONPATH=src:. python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.models import model as M
from repro.serve import ServeConfig, Server


def demo(arch: str, max_new=24):
    cfg = C.get_config(arch, smoke=True, dtype=jnp.float32)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    srv = Server(cfg, params, ServeConfig(max_len=96, temperature=0.7, seed=1))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0, cfg.vocab_size)
    cache = M.init_cache(cfg, 4, 96)
    cache_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
    t0 = time.time()
    out = srv.generate({"tokens": toks}, max_new_tokens=max_new)
    dt = time.time() - t0
    print(f"{arch:24s} cache={cache_bytes/1e6:7.2f} MB  "
          f"{out.shape[0]}x{out.shape[1]} tokens in {dt:5.2f}s")
    return out


if __name__ == "__main__":
    print("batched generation (4 sequences), per cache family:")
    demo("minicpm-2b")        # dense: full KV
    demo("h2o-danube-3-4b")   # SWA: ring buffer
    demo("mamba2-130m")       # SSM: constant state
    demo("hymba-1.5b")        # hybrid: ring + state

"""Serving example: per-family caches + continuous batching over paged KV.

Part 1 shows the per-family cache behaviour the static-wave engine manages:
  * minicpm (dense MHA)      — full KV cache,
  * h2o-danube (SWA)         — O(window) ring buffer,
  * mamba2 (SSM)             — O(1) state.

Part 2 runs the same dense model through the continuous-batching engine:
requests arrive staggered, are admitted when the block-paged KV cache has
pages free (page size = the accelerator kernel block, cfg.block), and a
finished request's slot is re-filled the same step.

Run:  PYTHONPATH=src:. python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.models import model as M
from repro.serve import Engine, EngineConfig, ServeConfig, Server, make_requests


def demo(arch: str, max_new=24):
    cfg = C.get_config(arch, smoke=True, dtype=jnp.float32)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    srv = Server(cfg, params, ServeConfig(max_len=96, temperature=0.7, seed=1))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0, cfg.vocab_size)
    cache = M.init_cache(cfg, 4, 96)
    cache_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
    t0 = time.time()
    out = srv.generate({"tokens": toks}, max_new_tokens=max_new)
    dt = time.time() - t0
    print(f"{arch:24s} cache={cache_bytes/1e6:7.2f} MB  "
          f"{out.shape[0]}x{out.shape[1]} tokens in {dt:5.2f}s")
    return out


def demo_continuous(arch: str, num_requests=6):
    cfg = C.get_config(arch, smoke=True, dtype=jnp.float32)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, EngineConfig(max_seqs=2, max_len=40, page_size=8))
    for r in make_requests(cfg.vocab_size, num_requests, prompt_len=12,
                           max_new=16, mean_interarrival=4.0):
        eng.submit(r["prompt"], r["max_new_tokens"],
                   rid=r["rid"], arrival_step=r["arrival_step"])
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    print(f"{arch:24s} {num_requests} requests / 2 slots, "
          f"page={eng.kv.page_size} cache={eng.kv.cache_bytes()/1e6:.2f} MB: "
          f"{n_tok} tokens in {dt:.2f}s ({eng.decode_steps} decode steps)")
    for r in done:
        print(f"   rid {r.rid}: arrived step {r.stats.arrival_step:2d}, "
              f"queued {r.stats.queue_steps} steps, "
              f"{len(r.out_tokens)} tokens, "
              f"first 6: {np.asarray(r.out_tokens[:6])}")


if __name__ == "__main__":
    print("batched generation (4 sequences), per cache family:")
    demo("minicpm-2b")        # dense: full KV
    demo("h2o-danube-3-4b")   # SWA: ring buffer
    demo("mamba2-130m")       # SSM: constant state
    demo("hymba-1.5b")        # hybrid: ring + state
    print("\ncontinuous batching over the block-paged KV cache:")
    demo_continuous("minicpm-2b")

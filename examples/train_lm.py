"""End-to-end training driver: ~100M-param LM for a few hundred steps.

Uses the full framework path: config -> sharded trainer (local mesh) ->
synthetic data pipeline -> AdamW + cosine -> async checkpoints -> restart.

Run:  PYTHONPATH=src:. python examples/train_lm.py [--steps 300]
(~100M params on CPU: expect a few seconds/step. --tiny for a quick check.)
"""
import argparse
import tempfile

import jax.numpy as jnp

import repro.configs as C
from repro.data import SyntheticLMData
from repro.launch.mesh import make_local_mesh
from repro.optim import OptConfig
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    args = ap.parse_args()

    if args.tiny:
        cfg = C.get_config("minicpm-2b", smoke=True, dtype=jnp.float32)
        batch, seq = 8, 64
    else:
        # ~100M-param llama-style model (minicpm family, scaled down)
        cfg = C.get_config(
            "minicpm-2b", dtype=jnp.float32,
            n_layers=8, d_model=512, n_heads=8, n_kv_heads=8, d_head=64,
            d_ff=2048, vocab_size=32000, q_chunk=128,
        )
        batch, seq = 16, 256
    n = cfg.param_count()
    print(f"model: {cfg.name}  ~{n/1e6:.0f}M params")

    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    tc = TrainerConfig(
        steps=args.steps, checkpoint_every=100, checkpoint_dir=ckpt_dir,
        log_every=10, step_deadline_s=300.0,
    )
    tr = Trainer(cfg, make_local_mesh(), tc, OptConfig(lr=3e-4))
    data = SyntheticLMData(cfg, global_batch=batch, seq_len=seq)
    params, opt, hist = tr.fit(data)
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"(checkpoints in {ckpt_dir})")


if __name__ == "__main__":
    main()

"""BWMA reproduction: accelerator-driven block-wise data arrangement.

Package layout (see README.md for the map): ``core`` holds the paper's
layout/blockwise/encoder/memmodel machinery, ``kernels`` the Pallas BWMA
kernels, and the remaining subpackages the production-scale system around
them (models, distributed, serving, training).
"""

"""Analysis tools: the paper's analytic cost model (:mod:`analytic`), the
roofline sweep (:mod:`roofline`), and the repo-specific static lint pass +
runtime sanitizer harness (:mod:`staticcheck`)."""

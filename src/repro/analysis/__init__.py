"""Analysis tools: the paper's analytic cost model (:mod:`analytic`), the
roofline sweep (:mod:`roofline`), the repo-specific static lint pass +
runtime sanitizer harness (:mod:`staticcheck`), the shared AOT
lower/compile machinery (:mod:`aot`), and the compiled-artifact linter
over the serving engine's jitted steps (:mod:`jaxcheck`)."""

# repro: noqa-file RPR004 -- the paper's analytic cost model is inherently
# per-family math; it never executes layers, so the registry rule is moot
"""Analytic roofline term calculator.

Why analytic: XLA's ``compiled.cost_analysis()`` counts a while-loop body
(our layer scan, microbatch scan, attention-chunk map) ONCE — for a scanned
61-layer model the reported FLOPs/bytes are ~L× too small.  The dry-run HLO
remains the *evidence* for the collective schedule and per-buffer memory;
the roofline terms themselves come from the model math below, which we can
state exactly because we wrote the model.

All quantities are PER DEVICE PER STEP unless suffixed ``_global``.

Sharding assumptions (must match distributed/sharding.py):
  * weights stored ZeRO-sharded over all ``n_dev`` devices; TP shard is
    ``1/tp`` of each tensor, the dp extension holds storage only;
  * compute-time weights are gathered over dp → each device streams the
    full ``1/tp`` TP shard per use (fwd, remat-fwd, bwd);
  * batch over dp; TP activations all-reduced twice per layer (Megatron),
    twice more in backward;
  * MoE dispatch/combine are all-to-alls of the routed token embeddings;
  * decode reads the whole cache shard + the full (1/tp) weight shard per
    token; FSDP weight gathers cross the network every step.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import SHAPES, ModelConfig

PEAK_FLOPS = 197e12  # bf16 / chip (TPU v5e)
HBM_BW = 819e9
ICI_BW = 50e9

BYTES_P = 2  # bf16 params/activations


@dataclasses.dataclass(frozen=True)
class MeshInfo:
    n_dev: int
    dp: int
    tp: int

    @staticmethod
    def single():
        return MeshInfo(256, 16, 16)

    @staticmethod
    def multi():
        return MeshInfo(512, 32, 16)


def _emb_params(cfg: ModelConfig) -> int:
    return cfg.padded_vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)


def _attn_ctx(cfg: ModelConfig, S: int) -> int:
    """Effective attended context length per query token (avg)."""
    if cfg.family == "ssm":
        return 0
    ctx = S // 2  # causal average
    if cfg.attn_type == "swa":
        ctx = min(ctx, cfg.window)
    return ctx


def step_flops_global(cfg: ModelConfig, shape_name: str) -> float:
    """Exact-ish FLOPs for one step (matmuls only; elementwise ~1%)."""
    shape = SHAPES[shape_name]
    B = shape.global_batch
    if shape.kind == "decode":
        tokens, S_ctx = B, shape.seq_len  # one new token, full cache context
    else:
        tokens, S_ctx = B * shape.seq_len, _attn_ctx(cfg, shape.seq_len)
    n_mat = cfg.active_param_count() - _emb_params(cfg)
    per_tok = 2 * n_mat
    # attention score+value matmuls: 2*2*H*dh*ctx per token per layer
    if cfg.family != "ssm":
        H, dh = cfg.n_heads, (cfg.v_head_dim or cfg.d_head)
        qk_dim = cfg.qk_head_dim
        n_attn_layers = cfg.n_layers
        per_tok += 2 * H * (qk_dim + dh) * S_ctx * n_attn_layers
    if cfg.family in ("ssm", "hybrid"):
        # SSD: intra-chunk quadratic + state updates ~ 2*Q*d_inner + state
        Q = cfg.ssm_chunk
        per_tok += cfg.n_layers * (
            2 * Q * cfg.d_inner + 4 * cfg.d_inner * cfg.ssm_state
        )
    # logits head (train computes all positions; prefill/decode only new)
    logit_toks = tokens if shape.kind == "train" else B
    logits = 2 * cfg.d_model * cfg.padded_vocab * logit_toks
    mult = 3 if shape.kind == "train" else 1  # fwd+bwd
    return mult * (per_tok * tokens + logits) * 1.0


def cache_bytes_global(cfg: ModelConfig, shape_name: str) -> float:
    shape = SHAPES[shape_name]
    if shape.kind != "decode":
        return 0.0
    B, S = shape.global_batch, shape.seq_len
    if cfg.attn_type == "mla":
        per_tok = cfg.kv_lora_rank + cfg.qk_rope_dim
        slots = S
    elif cfg.family == "ssm":
        st = cfg.ssm_nheads * cfg.ssm_headdim * cfg.ssm_state * 4
        return cfg.n_layers * B * st
    elif cfg.attn_type == "swa":
        per_tok = 2 * cfg.n_kv_heads * cfg.d_head
        slots = min(S, cfg.window)
    else:
        per_tok = 2 * cfg.n_kv_heads * cfg.d_head
        slots = S
    total = cfg.n_layers * B * slots * per_tok * BYTES_P
    if cfg.family == "hybrid":
        st = cfg.ssm_nheads * cfg.ssm_headdim * cfg.ssm_state * 4
        total += cfg.n_layers * B * st
    return total


REPLICATE_BELOW = 5e8  # must match distributed/sharding.py


def hbm_bytes_per_device(cfg: ModelConfig, shape_name: str, mesh: MeshInfo,
                         accum: int = 1) -> float:
    shape = SHAPES[shape_name]
    N = cfg.param_count()
    w_stream = N * BYTES_P / mesh.tp  # full TP shard streamed per use
    if shape.kind == "decode":
        # serve mode: 2-D TP over ALL axes, weights resident
        w_stream = N * BYTES_P / mesh.n_dev
    elif N < REPLICATE_BELOW:
        w_stream = N * BYTES_P  # replicated small model
    if shape.kind == "train":
        toks_dev = shape.global_batch * shape.seq_len // mesh.n_dev
        w = 3 * accum * w_stream  # fwd + remat-fwd + bwd
        opt = 16 * N / mesh.n_dev  # p/m/v read+write, fp32 math
        act = 12 * toks_dev * cfg.d_model * BYTES_P * cfg.n_layers
        logits = 4 * toks_dev * cfg.padded_vocab * BYTES_P
        return w + opt + act + logits
    if shape.kind == "prefill":
        toks_dev = shape.global_batch * shape.seq_len // mesh.n_dev
        act = 8 * toks_dev * cfg.d_model * BYTES_P * cfg.n_layers
        return w_stream + act
    # decode
    cache = 2 * cache_bytes_global(cfg, shape_name) / mesh.n_dev
    return w_stream + cache


def collective_bytes_per_device(cfg: ModelConfig, shape_name: str,
                                mesh: MeshInfo, accum: int = 1) -> float:
    """TP all-reduces + FSDP gathers/reduce-scatters + MoE all-to-alls."""
    shape = SHAPES[shape_name]
    N = cfg.param_count()
    fsdp_gather = N * BYTES_P / mesh.tp * (mesh.dp - 1) / mesh.dp
    if N < REPLICATE_BELOW and shape.kind == "train":
        # replicated small model: no gathers, only the f32 grad all-reduce
        toks_dev_mb = (shape.global_batch * shape.seq_len
                       // mesh.n_dev // max(accum, 1))
        return N * 4 * 2 * (mesh.n_dev - 1) / mesh.n_dev
    if shape.kind == "train":
        toks_dev_mb = (shape.global_batch * shape.seq_len
                       // mesh.n_dev // max(accum, 1))
        tp_ar = (4 * cfg.n_layers * toks_dev_mb * cfg.d_model * BYTES_P
                 * 2 * (mesh.tp - 1) / mesh.tp) * accum
        grads_rs = N * 4 / mesh.tp * (mesh.dp - 1) / mesh.dp
        gathers = 2 * accum * fsdp_gather  # fwd+bwd weight gathers / microbatch
        moe = 0.0
        if cfg.n_experts:
            moe = (4 * 2 * shape.global_batch * shape.seq_len // mesh.n_dev
                   * cfg.top_k * cfg.d_model * BYTES_P) * accum / accum
        return tp_ar + grads_rs + gathers + moe
    if shape.kind == "decode":
        # serve mode: weights resident (no gathers); TP all-reduce over all
        # axes of the (tokens, d) activations per layer
        toks = shape.global_batch
        tp_ar = 2 * cfg.n_layers * toks * cfg.d_model * BYTES_P * (
            (mesh.n_dev - 1) / mesh.n_dev)
        moe = (2 * 2 * toks * cfg.top_k * cfg.d_model * BYTES_P
               if cfg.n_experts else 0.0)
        return tp_ar + moe
    toks_dev = max(1, shape.global_batch * shape.seq_len // mesh.n_dev)
    tp_ar = 2 * cfg.n_layers * toks_dev * cfg.d_model * BYTES_P * (
        (mesh.tp - 1) / mesh.tp)
    moe = 0.0
    if cfg.n_experts:
        moe = 2 * 2 * toks_dev * cfg.top_k * cfg.d_model * BYTES_P
    return fsdp_gather + tp_ar + moe


def roofline_terms(cfg: ModelConfig, shape_name: str, mesh: MeshInfo,
                   accum: int = 1) -> Dict[str, float]:
    f_g = step_flops_global(cfg, shape_name)
    t_compute = f_g / (mesh.n_dev * PEAK_FLOPS)
    t_memory = hbm_bytes_per_device(cfg, shape_name, mesh, accum) / HBM_BW
    t_coll = collective_bytes_per_device(cfg, shape_name, mesh, accum) / ICI_BW
    terms = {
        "compute": t_compute, "memory": t_memory, "collective": t_coll,
    }
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    total = sum(terms.values())
    return {
        **terms,
        "dominant": dominant,
        "flops_global": f_g,
        # overlapped bound: step >= max(terms); serial bound: sum(terms).
        "roofline_fraction": t_compute / bound if bound else float("nan"),
        "roofline_fraction_serial": t_compute / total if total else float("nan"),
    }

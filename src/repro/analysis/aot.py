"""Shared AOT lower/compile machinery for compiled-artifact analysis.

``jax.jit(fn).lower(*args).compile()`` is the repo's standard way of turning
a step function into an inspectable artifact without executing it: the
multi-pod dry-run (:mod:`repro.launch.dryrun`) proves sharding configs
compile and records their memory/cost analyses, and the compiled-artifact
linter (:mod:`repro.analysis.jaxcheck`) statically checks the serving
engine's hot steps.  This module is the one place that machinery lives.

Arguments may be real arrays or :class:`jax.ShapeDtypeStruct` pytrees —
lowering never runs the computation either way.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Sequence, Tuple

import jax

#: ``CompiledMemoryStats`` fields recorded by :func:`memory_record` — the
#: exact set (and order) the dry-run has always persisted per cell.
MEMORY_FIELDS = (
    "temp_size_in_bytes",
    "argument_size_in_bytes",
    "output_size_in_bytes",
    "alias_size_in_bytes",
    "generated_code_size_in_bytes",
)


@dataclasses.dataclass
class AotArtifact:
    """One step function lowered and compiled ahead of time."""

    jitted: Any
    lowered: Any
    compiled: Any
    lower_s: float
    compile_s: float

    def memory_record(self) -> Dict[str, int]:
        return memory_record(self.compiled)

    def cost_analysis(self) -> Optional[Dict[str, float]]:
        return self.compiled.cost_analysis()

    def hlo_text(self) -> str:
        return self.compiled.as_text()


def lower_and_compile(
    fn,
    args: Sequence,
    *,
    in_shardings: Any = None,
    out_shardings: Any = None,
    donate_argnums: Tuple[int, ...] = (),
    keep_unused: bool = False,
    static_argnums: Tuple[int, ...] = (),
) -> AotArtifact:
    """Jit, lower, and compile ``fn`` on ``args``; never executes.

    ``keep_unused=True`` keeps every argument leaf as an executable
    parameter (jit prunes unused ones by default) — required when the
    caller maps flattened argument indices onto HLO parameter numbers
    (the donation-effectiveness check in jaxcheck).
    """
    kwargs: Dict[str, Any] = {}
    if in_shardings is not None:
        kwargs["in_shardings"] = in_shardings
    if out_shardings is not None:
        kwargs["out_shardings"] = out_shardings
    if keep_unused:
        kwargs["keep_unused"] = True
    if static_argnums:
        kwargs["static_argnums"] = static_argnums
    jitted = jax.jit(fn, donate_argnums=donate_argnums, **kwargs)
    t0 = time.time()
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    return AotArtifact(
        jitted=jitted,
        lowered=lowered,
        compiled=compiled,
        lower_s=t_lower,
        compile_s=t_compile,
    )


def memory_record(compiled) -> Dict[str, int]:
    """``compiled.memory_analysis()`` as a plain int dict (MEMORY_FIELDS
    present on this backend only — XLA:CPU reports all five)."""
    mem = compiled.memory_analysis()
    return {
        k: int(getattr(mem, k)) for k in MEMORY_FIELDS if hasattr(mem, k)
    }

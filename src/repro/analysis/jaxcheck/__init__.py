"""repro.analysis.jaxcheck — static analysis over *compiled* serving steps.

:mod:`repro.analysis.staticcheck` lints Python source; the hazards that
matter for the serving hot path live one level down, in the lowered jaxprs
and compiled executables of the engine's jitted steps: a donation can
silently fall back to a copy, a paged-decode gather can materialize the
full K/V in HBM, an upcast can creep into a hot step, a code change can
leak new jit-cache signatures, and the compiled memory footprint can
regress — all invisible to source-level lint and only *felt* as a slow
perf regression.  This package AOT-compiles the engine's jitted-step
inventory (:func:`repro.serve.engine.jitted_step_fns`, lowered via
:mod:`repro.analysis.aot` — the same ``lower().compile()`` machinery the
multi-pod dry-run uses) and proves the data-movement claims statically:

===========  ==================================================================
rule id      what it catches
===========  ==================================================================
``RPJ101``   donation-effectiveness: a buffer passed at a ``donate_argnums``
             position whose executable does **not** alias it to an output
             (``input_output_alias``) — the donation silently became a copy
``RPJ102``   materialized-gather: a ``gather`` op in the lowered jaxpr whose
             output bytes exceed the step's budget — the "full K/V gathered
             into HBM" hazard the paged kernels exist to avoid
``RPJ103``   dtype-promotion drift: ``convert_element_type`` introducing an
             upcast wider than the planned widest dtype inside a hot step
``RPJ104``   retrace-closure: a chunk shape escaping the statically
             enumerated jit-cache key set, or probe calls compiling more
             cache entries than the declared signature count
``RPJ105``   memory-budget regression: ``compiled.memory_analysis()``
             temp/argument/output bytes over the checked-in budget
``RPJ106``   collective-traffic budget: cross-device collectives
             (all-reduce / all-gather / reduce-scatter / all-to-all /
             collective-permute) GSPMD inserted into a *sharded* step's
             compiled module, summed by payload bytes — a sharding change
             that silently all-gathers the KV pool every decode step is a
             wire-traffic regression no single-device analysis can see
===========  ==================================================================

Budgets and waivers live in the checked-in ``jaxcheck.budgets`` file
(re-baseline with ``--write-budgets``); a step section may waive rules with
``waive = RPJ103 -- reason`` — the compiled-artifact twin of staticcheck's
``# repro: noqa`` pragmas.

CLI::

    python -m repro.analysis.jaxcheck --json-out BENCH_jaxcheck.json

Exit 0 when clean (modulo budgets/waivers), 1 on findings, 2 on usage
errors.  CPU-runnable: lowering and ``memory_analysis`` never execute the
steps; only the RPJ104 signature probes run (smoke-sized, tiny on CPU).
"""

from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

__all__ = [
    "Finding",
    "Budgets",
    "RULE_IDS",
    "RULE_DOCS",
    "load_budgets",
    "format_budgets",
]

RULE_IDS = ("RPJ101", "RPJ102", "RPJ103", "RPJ104", "RPJ105", "RPJ106")

RULE_DOCS = {
    "RPJ101": "donation-effectiveness: donated buffer not in input_output_aliases",
    "RPJ102": "materialized-gather: gather output bytes over the step's budget",
    "RPJ103": "dtype-promotion drift: upcast past the planned widest dtype",
    "RPJ104": "retrace-closure: jit signature outside the enumerated key set",
    "RPJ105": "memory-budget regression: compiled memory over checked-in budget",
    "RPJ106": "collective-traffic budget: sharded-step collective bytes over budget",
}

#: memory_analysis fields gated by RPJ105 (alias/codegen sizes are recorded
#: in the report but not gated — they track the other three)
GATED_MEMORY_FIELDS = (
    "temp_size_in_bytes",
    "argument_size_in_bytes",
    "output_size_in_bytes",
)

DEFAULT_TOLERANCE = 0.5  # compiled sizes may wobble across jaxlib builds
DEFAULT_WIDEST = "float32"  # the planned widest compute dtype in hot steps


@dataclasses.dataclass(frozen=True)
class Finding:
    """One compiled-artifact finding, reported as ``step: RULE message``."""

    rule: str
    step: str
    message: str

    def format(self) -> str:
        return f"{self.step}: {self.rule} {self.message}"

    def to_json(self) -> Dict[str, str]:
        return {"rule": self.rule, "step": self.step, "message": self.message}


# ---------------------------------------------------------------------------
# Budgets file (jaxcheck.budgets)
# ---------------------------------------------------------------------------
#
#   [global]
#   tolerance = 0.50
#   allowed_widest = float32
#
#   [decode_step]
#   temp_size_in_bytes = 1234
#   argument_size_in_bytes = 5678
#   output_size_in_bytes = 91011
#   max_gather_bytes = 1213
#   waive = RPJ103 -- reason
#
# Sections are step names from the AOT inventory; `waive` suppresses rules
# for that step (or globally, in [global]).  Regenerate measured values
# with `python -m repro.analysis.jaxcheck --write-budgets`.


@dataclasses.dataclass
class Budgets:
    """Parsed ``jaxcheck.budgets``: per-step numeric budgets + waivers."""

    steps: Dict[str, Dict[str, int]] = dataclasses.field(default_factory=dict)
    waivers: Dict[str, Set[str]] = dataclasses.field(default_factory=dict)
    tolerance: float = DEFAULT_TOLERANCE
    allowed_widest: str = DEFAULT_WIDEST

    def budget(self, step: str, key: str) -> Optional[int]:
        return self.steps.get(step, {}).get(key)

    def waived(self, rule: str, step: str) -> bool:
        return rule in self.waivers.get(step, set()) or (
            rule in self.waivers.get("global", set())
        )

    def allowed(self, step: str, key: str, value: int) -> bool:
        """Within budget: ``value <= budget * (1 + tolerance)``."""
        b = self.budget(step, key)
        if b is None:
            return False
        return value <= b * (1.0 + self.tolerance)


def _parse_waive(value: str, where: str) -> Set[str]:
    rules_part = value.split("--", 1)[0]
    rules = {t.strip() for t in rules_part.split(",") if t.strip()}
    unknown = rules - set(RULE_IDS)
    if unknown:
        raise ValueError(f"{where}: unknown rule id(s) in waive: {sorted(unknown)}")
    if not rules:
        raise ValueError(f"{where}: empty waive entry")
    return rules


def load_budgets(path: Path) -> Budgets:
    budgets = Budgets()
    section = None
    for lineno, raw in enumerate(path.read_text(encoding="utf-8").splitlines(), 1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        where = f"{path}:{lineno}"
        if line.startswith("[") and line.endswith("]"):
            section = line[1:-1].strip()
            if not section:
                raise ValueError(f"{where}: empty section name")
            continue
        if "=" not in line or section is None:
            raise ValueError(f"{where}: expected `key = value` inside a section")
        key, value = (t.strip() for t in line.split("=", 1))
        if key == "waive":
            budgets.waivers.setdefault(section, set()).update(
                _parse_waive(value, where)
            )
        elif section == "global" and key == "tolerance":
            budgets.tolerance = float(value)
        elif section == "global" and key == "allowed_widest":
            budgets.allowed_widest = value
        else:
            try:
                budgets.steps.setdefault(section, {})[key] = int(value)
            except ValueError:
                raise ValueError(
                    f"{where}: budget value for {key} must be an int"
                ) from None
    return budgets


def format_budgets(
    measured: Dict[str, Dict[str, int]],
    *,
    tolerance: float = DEFAULT_TOLERANCE,
    allowed_widest: str = DEFAULT_WIDEST,
    waivers: Optional[Dict[str, Set[str]]] = None,
) -> str:
    """Serialize measured per-step budgets (preserving waivers on rewrite)."""
    lines = [
        "# jaxcheck.budgets — compiled-artifact budgets for the serving",
        "# engine's jitted steps (gather bytes, memory_analysis sizes).",
        "# Regenerate with: python -m repro.analysis.jaxcheck --write-budgets",
        "# `waive = RPJxxx -- reason` suppresses a rule for a step.",
        "",
        "[global]",
        f"tolerance = {tolerance:.2f}",
        f"allowed_widest = {allowed_widest}",
    ]
    waivers = waivers or {}
    if "global" in waivers:
        lines.append(f"waive = {', '.join(sorted(waivers['global']))}")
    for step in sorted(measured):
        lines.append("")
        lines.append(f"[{step}]")
        for key in sorted(measured[step]):
            lines.append(f"{key} = {measured[step][key]}")
        if step in waivers:
            lines.append(f"waive = {', '.join(sorted(waivers[step]))}")
    return "\n".join(lines) + "\n"

# repro: noqa-file RPR005 -- CLI driver: the findings prints ARE the output
"""CLI: ``python -m repro.analysis.jaxcheck``.

Compiles the serving engine's jitted-step inventory ahead of time and runs
the RPJ rules against the artifacts.  Exit 0 when clean (modulo the
checked-in ``jaxcheck.budgets``), 1 on findings, 2 on usage errors.

  # check the tree against the checked-in budgets
  PYTHONPATH=src python -m repro.analysis.jaxcheck

  # re-baseline after an intentional memory/gather change
  PYTHONPATH=src python -m repro.analysis.jaxcheck --write-budgets

  # CI report artifact
  PYTHONPATH=src python -m repro.analysis.jaxcheck --json-out BENCH_jaxcheck.json
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.jaxcheck import (
    DEFAULT_TOLERANCE,
    DEFAULT_WIDEST,
    RULE_DOCS,
    RULE_IDS,
    Budgets,
    format_budgets,
    load_budgets,
)
from repro.analysis.jaxcheck.harness import compile_step, measure
from repro.analysis.jaxcheck.inventory import InventoryConfig, serving_inventory
from repro.analysis.jaxcheck.rules import run_rules


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.jaxcheck",
        description="static analysis over the engine's compiled jitted steps",
    )
    ap.add_argument("--arch", default="minicpm-2b",
                    help="model config to compile the inventory at")
    ap.add_argument("--max-seqs", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--backend", default="pallas",
                    choices=("reference", "pallas"),
                    help="decode/COW path the primary decode_step and "
                         "cow_copy specs compile (default: pallas)")
    ap.add_argument("--mesh", default="",
                    help="DxM mesh spec (e.g. 1x2): compile the SHARDED "
                         "inventory — needs D*M visible devices (simulate "
                         "with XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N) and gates RPJ106 collective budgets")
    ap.add_argument("--budgets", default=None,
                    help="budgets/waivers file (default: ./jaxcheck.budgets, "
                         "or ./jaxcheck_mesh.budgets under --mesh)")
    ap.add_argument("--write-budgets", action="store_true",
                    help="measure and (re)write the budgets file, keep waivers")
    ap.add_argument("--select", nargs="+", choices=RULE_IDS, default=None,
                    help="run only these rules")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--json-out", default=None,
                    help="write a JSON report (BENCH_jaxcheck.json in CI)")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid in RULE_IDS:
            print(f"{rid}  {RULE_DOCS[rid]}")
        return 0

    geometry = InventoryConfig(
        arch=args.arch, max_seqs=args.max_seqs, max_len=args.max_len,
        page_size=args.page_size, backend=args.backend, mesh=args.mesh,
    )
    inv = serving_inventory(geometry)
    steps = [compile_step(spec) for spec in inv.specs]
    measured = {cs.name: measure(cs) for cs in steps}
    # mesh budgets live in their own file: the sharded modules' sizes (and
    # collectives) are a different baseline than the single-device ones
    budgets_path = Path(args.budgets or (
        "jaxcheck_mesh.budgets" if args.mesh else "jaxcheck.budgets"
    ))

    if args.write_budgets:
        tolerance, widest, waivers = DEFAULT_TOLERANCE, DEFAULT_WIDEST, None
        if budgets_path.exists():  # keep waivers + global knobs on rewrite
            old = load_budgets(budgets_path)
            tolerance, widest, waivers = (
                old.tolerance, old.allowed_widest, old.waivers
            )
        budgets_path.write_text(format_budgets(
            measured, tolerance=tolerance, allowed_widest=widest,
            waivers=waivers,
        ), encoding="utf-8")
        print(f"wrote {budgets_path} ({len(measured)} steps)")
        return 0

    if budgets_path.exists():
        try:
            budgets = load_budgets(budgets_path)
        except ValueError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
    else:
        print(f"note: {budgets_path} not found — RPJ102/RPJ105 will report "
              f"unbudgeted steps; run --write-budgets to baseline",
              file=sys.stderr)
        budgets = Budgets()

    findings = run_rules(steps, inv, budgets, select=args.select)
    for f in findings:
        print(f.format())

    if args.json_out:
        report = {
            "tool": "jaxcheck",
            "arch": args.arch,
            "geometry": {
                "max_seqs": args.max_seqs, "max_len": args.max_len,
                "page_size": args.page_size, "backend": args.backend,
                "mesh": args.mesh,
            },
            "chunk_size": inv.chunk_size,
            "chunk_closure": list(inv.chunk_closure),
            "n_steps": len(steps),
            "steps": measured,
            "findings": [f.to_json() for f in findings],
            "status": "findings" if findings else "clean",
        }
        Path(args.json_out).write_text(
            json.dumps(report, indent=2) + "\n", encoding="utf-8"
        )

    n = len(findings)
    print(f"jaxcheck: {len(steps)} compiled steps, "
          f"{n} finding{'s' if n != 1 else ''}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())

"""AOT harness: turn step specs into inspectable compiled artifacts.

A :class:`StepSpec` names one jitted hot-path step (un-jitted callable +
abstract example arguments + donate positions, plus the optional RPJ104
signature-probe declaration); :func:`compile_step` lowers and compiles it
through the shared machinery in :mod:`repro.analysis.aot` and extracts the
facts the rules consume:

* the closed jaxpr (recursively walkable — gathers and converts hide
  inside nested ``pjit``/``scan``/``cond`` sub-jaxprs),
* the executable's ``input_output_alias`` parameter set, mapped against
  the flattened donated-argument leaves (``keep_unused=True`` keeps the
  flat-index -> HLO-parameter-number mapping the identity),
* the ``memory_analysis()`` record.

Nothing here executes a step; only the RPJ104 probe driver
(:func:`rules.rule_rpj104`) runs real (smoke-sized) calls.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Dict, FrozenSet, Iterator, List, Optional, Sequence, Tuple

import jax

from repro.analysis.aot import AotArtifact, lower_and_compile


@dataclasses.dataclass
class ProbeSet:
    """RPJ104 signature probes: real-argument factories driven through a
    fresh jit whose compiled-entry count must land on ``expected_entries``.

    ``keys`` may intentionally repeat a signature (two calls that must
    share one trace); ``make_args(key)`` must return *fresh* buffers every
    call — donated arguments are consumed."""

    keys: Sequence[Any]
    make_args: Callable[[Any], tuple]
    expected_entries: int


@dataclasses.dataclass
class StepSpec:
    """One jitted hot-path step, declared for AOT analysis."""

    name: str
    fn: Callable
    args: tuple  # pytrees of jax.ShapeDtypeStruct (or real arrays)
    donate_argnums: Tuple[int, ...] = ()
    probe: Optional[ProbeSet] = None
    #: RPJ104 static closure: the signature keys admission is planned to
    #: emit, and the closed set they must stay inside
    signature_plan: Optional[Sequence[Any]] = None
    signature_closure: Optional[Sequence[Any]] = None
    #: mesh inventory (sjit): per-argument / per-output NamedSharding trees
    #: passed through to lowering, so the compiled artifact is the real
    #: GSPMD-partitioned module (whose collectives RPJ106 budgets)
    in_shardings: Any = None
    out_shardings: Any = None


@dataclasses.dataclass
class CompiledStep:
    """A step spec plus everything the rules read off its artifacts."""

    spec: StepSpec
    artifact: AotArtifact
    jaxpr: Any  # ClosedJaxpr
    donated_params: FrozenSet[int]  # flat arg indices asked to donate
    aliased_params: FrozenSet[int]  # HLO parameter numbers actually aliased
    donated_leaf_labels: Dict[int, str]  # flat index -> human label
    memory: Dict[str, int]

    @property
    def name(self) -> str:
        return self.spec.name


# ---------------------------------------------------------------------------
# Jaxpr walking
# ---------------------------------------------------------------------------


def iter_eqns(jaxpr) -> Iterator[Any]:
    """Depth-first over every eqn of a (Closed)Jaxpr, including the
    sub-jaxprs of pjit / scan / while / cond / custom-derivative calls."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            for sub in _sub_jaxprs(val):
                yield from iter_eqns(sub)


def _sub_jaxprs(val) -> List[Any]:
    if hasattr(val, "jaxpr") or hasattr(val, "eqns"):
        return [val]
    if isinstance(val, (list, tuple)):
        return [v for v in val if hasattr(v, "jaxpr") or hasattr(v, "eqns")]
    return []


def aval_bytes(aval) -> int:
    size = 1
    for d in aval.shape:
        size *= int(d)
    return size * aval.dtype.itemsize


def gather_stats(jaxpr) -> List[Dict[str, int]]:
    """Every ``gather`` eqn's (output bytes, source-operand bytes)."""
    out = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != "gather":
            continue
        out_b = sum(aval_bytes(v.aval) for v in eqn.outvars)
        src_b = aval_bytes(eqn.invars[0].aval)
        out.append({"output_bytes": out_b, "source_bytes": src_b})
    return out


def convert_stats(jaxpr) -> List[Dict[str, Any]]:
    """Every ``convert_element_type`` eqn's (from, to, output bytes)."""
    out = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != "convert_element_type":
            continue
        out.append({
            "from": str(eqn.invars[0].aval.dtype),
            "to": str(eqn.outvars[0].aval.dtype),
            "to_itemsize": eqn.outvars[0].aval.dtype.itemsize,
            "output_bytes": aval_bytes(eqn.outvars[0].aval),
        })
    return out


# ---------------------------------------------------------------------------
# Donation / alias extraction
# ---------------------------------------------------------------------------

_ALIAS_PARAM_RE = re.compile(r"\((\d+), \{")


def parse_aliased_params(hlo_text: str) -> FrozenSet[int]:
    """HLO parameter numbers appearing in the module's ``input_output_alias``
    attribute (empty when no donation survived compilation)."""
    start = hlo_text.find("input_output_alias={")
    if start < 0:
        return frozenset()
    # scan the balanced-brace attribute body (entries nest one brace deep)
    i = start + len("input_output_alias=")
    depth = 0
    for j in range(i, len(hlo_text)):
        if hlo_text[j] == "{":
            depth += 1
        elif hlo_text[j] == "}":
            depth -= 1
            if depth == 0:
                body = hlo_text[i : j + 1]
                return frozenset(int(m) for m in _ALIAS_PARAM_RE.findall(body))
    return frozenset()


# ---------------------------------------------------------------------------
# Collective extraction (compiled HLO text)
# ---------------------------------------------------------------------------
#
# GSPMD inserts cross-device collectives during SPMD partitioning, AFTER
# lowering — they exist only in the compiled module, never in the jaxpr, so
# unlike gathers/converts they must be read off ``compiled.as_text()``.

_COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

#: HLO dtype token -> itemsize (collective payloads only carry these)
_HLO_ITEMSIZE = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_HLO_SHAPE_RE = re.compile(r"([a-z]+[0-9]*)\[([0-9,]*)\]")
_HLO_EQN_RE = re.compile(r"=\s+(\(?[^)=]*?\)?)\s+([a-z][a-z0-9-]*)\(")


def _shape_bytes(shape_text: str) -> int:
    """Total bytes of an HLO shape string (tuples sum their elements)."""
    total = 0
    for dtype, dims in _HLO_SHAPE_RE.findall(shape_text):
        if dtype not in _HLO_ITEMSIZE:
            continue  # token/opaque shapes carry no payload
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _HLO_ITEMSIZE[dtype]
    return total


def collective_stats(hlo_text: str) -> List[Dict[str, Any]]:
    """Every collective op in a compiled module's HLO text: (op, output
    bytes).  Async pairs count once — the ``-start`` op carries the shape,
    the ``-done`` is skipped."""
    out = []
    for line in hlo_text.splitlines():
        m = _HLO_EQN_RE.search(line)
        if m is None:
            continue
        shape_text, op = m.group(1), m.group(2)
        if op.endswith("-done"):
            continue
        base = op[: -len("-start")] if op.endswith("-start") else op
        if base not in _COLLECTIVE_OPS:
            continue
        out.append({"op": base, "output_bytes": _shape_bytes(shape_text)})
    return out


def _leaf_label(path) -> str:
    return jax.tree_util.keystr(path)


def donated_leaf_map(
    args: Sequence, donate_argnums: Tuple[int, ...]
) -> Dict[int, str]:
    """Flat leaf index -> label for every leaf of every donated argument.

    With ``keep_unused=True`` the executable keeps one parameter per
    flattened argument leaf, in flatten order, so the flat index *is* the
    HLO parameter number."""
    donated: Dict[int, str] = {}
    offset = 0
    for i, arg in enumerate(args):
        leaves = jax.tree_util.tree_leaves_with_path(arg)
        if i in donate_argnums:
            for k, (path, _leaf) in enumerate(leaves):
                donated[offset + k] = f"arg{i}{_leaf_label(path)}"
        offset += len(leaves)
    return donated


# ---------------------------------------------------------------------------
# Compilation
# ---------------------------------------------------------------------------


def compile_step(spec: StepSpec) -> CompiledStep:
    """Lower + compile one step spec and extract the rule-facing facts."""
    jaxpr = jax.make_jaxpr(spec.fn)(*spec.args)
    artifact = lower_and_compile(
        spec.fn,
        spec.args,
        in_shardings=spec.in_shardings,
        out_shardings=spec.out_shardings,
        donate_argnums=spec.donate_argnums,
        keep_unused=True,
    )
    leaf_labels = donated_leaf_map(spec.args, spec.donate_argnums)
    return CompiledStep(
        spec=spec,
        artifact=artifact,
        jaxpr=jaxpr,
        donated_params=frozenset(leaf_labels),
        aliased_params=parse_aliased_params(artifact.hlo_text()),
        donated_leaf_labels=leaf_labels,
        memory=artifact.memory_record(),
    )


def measure(cs: CompiledStep) -> Dict[str, int]:
    """The numbers ``--write-budgets`` checks in for one compiled step."""
    gathers = gather_stats(cs.jaxpr)
    record = dict(cs.memory)
    record["max_gather_bytes"] = max(
        (g["output_bytes"] for g in gathers), default=0
    )
    record["collective_bytes"] = sum(
        c["output_bytes"] for c in collective_stats(cs.artifact.hlo_text())
    )
    return record

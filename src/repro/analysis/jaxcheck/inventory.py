"""The serving engine's jitted-step inventory, as AOT step specs.

Builds a :class:`StepSpec` per hot-path jit signature of the continuous
engine — the decode step, one prefill chunk per shape in the engine's
chunk-shape closure, the COW page copy, and the unchunked prefill install —
from the same callables the runtime jits
(:func:`repro.serve.engine.jitted_step_fns`).  The decode and COW specs
compile the kernelized (pallas) hot path by default and keep probe-less
``*_reference`` twins for the jnp oracle; see
:attr:`InventoryConfig.backend`.  Arguments are
``ShapeDtypeStruct`` pytrees at a smoke-sized geometry (the same shapes
``tests/test_sanitize.py`` exercises), so everything here lowers and
compiles on CPU without touching real buffers; only the RPJ104 probes
(declared here, run by the rules) allocate real smoke-sized arrays.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.analysis.jaxcheck.harness import ProbeSet, StepSpec
from repro.models import model as M
from repro.serve import engine as E


@dataclasses.dataclass(frozen=True)
class InventoryConfig:
    """Geometry the inventory compiles at (smoke-sized; shapes only)."""

    arch: str = "minicpm-2b"
    max_seqs: int = 2
    max_len: int = 64
    page_size: int = 8
    #: prompt lengths the RPJ104 closure check plans chunks for — a short
    #: prompt (ragged bucket), an exact chunk, and a multi-chunk prompt
    probe_prompt_lens: Tuple[int, ...] = (3, 8, 13)
    #: decode/COW execution path the PRIMARY ``decode_step`` / ``cow_copy``
    #: specs compile (``cfg.decode_backend``).  The default is the
    #: kernelized pallas path — the serving hot loop this analysis exists
    #: to budget: streaming pages through the fused kernel removes the
    #: whole-history ``k_pages[page_table]`` gather from the lowered step,
    #: which is exactly the RPJ102 ``max_gather_bytes`` drop the paper's
    #: arrangement argument predicts.  The jnp oracle path stays gated as
    #: ``decode_step_reference`` / ``cow_copy_reference``.
    backend: str = "pallas"
    #: ``DxM`` mesh spec (e.g. ``"1x2"``): compile the SHARDED inventory —
    #: every spec carries the engine's real in/out shardings (resident-TP
    #: params, head-sharded pools, replicated host-fed inputs) and traces
    #: under the mesh, so RPJ101 proves donation survives sharding and
    #: RPJ106 budgets the partitioned module's collective traffic.  Needs
    #: D*M visible devices; empty = single-device inventory.
    mesh: str = ""


@dataclasses.dataclass
class Inventory:
    """Everything jaxcheck analyzes: step specs + the RPJ104 closure."""

    cfg: Any
    geometry: InventoryConfig
    specs: List[StepSpec]
    chunk_size: int
    chunk_closure: Tuple[int, ...]
    chunk_plans: Dict[int, List[int]]  # probe prompt len -> planned shapes


def model_config(inv: InventoryConfig):
    cfg = C.get_config(inv.arch, smoke=True, dtype=jnp.float32)
    return dataclasses.replace(cfg, block=inv.page_size)


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


class _ProbeArena:
    """Lazily-built real smoke-sized state for the RPJ104 probes.

    Params are never donated by any step, so one copy is shared across all
    probe calls; the cache pool IS donated (consumed) by every step, so
    :meth:`fresh_caches` builds a new pool per call.
    """

    def __init__(self, cfg, inv: InventoryConfig, num_pages: int):
        self.cfg = cfg
        self.inv = inv
        self.num_pages = num_pages
        self._params = None

    def params(self):
        if self._params is None:
            self._params = M.init_params(self.cfg, jax.random.PRNGKey(0))
        return self._params

    def fresh_caches(self):
        return M.init_paged_cache(
            self.cfg, self.inv.max_seqs, self.num_pages,
            self.inv.page_size, self.inv.max_len,
        )


def serving_inventory(inv: Optional[InventoryConfig] = None) -> Inventory:
    inv = inv or InventoryConfig()
    cfg = model_config(inv)
    # two step tables: the kernelized hot path the budgets gate (pallas by
    # default, see InventoryConfig.backend) and the jnp oracle it must keep
    # matching.  Only decode/COW dispatch on decode_backend; prefill chunks
    # and install lower identically, so they come from the reference table.
    cfg_hot = dataclasses.replace(cfg, decode_backend=inv.backend)
    steps = E.jitted_step_fns(cfg)
    steps_hot = E.jitted_step_fns(cfg_hot)
    max_pages = max(1, -(-inv.max_len // inv.page_size))
    num_pages = inv.max_seqs * max_pages + 1
    B = inv.max_seqs
    params = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    caches = jax.eval_shape(
        lambda: M.init_paged_cache(
            cfg, inv.max_seqs, num_pages, inv.page_size, inv.max_len
        )
    )
    chunk_size = E.resolve_chunk_size(cfg, inv.page_size)
    closure = E.chunk_shape_set(cfg, chunk_size)
    plans = {
        n: E.chunk_plan(cfg, chunk_size, n) for n in inv.probe_prompt_lens
    }
    arena = _ProbeArena(cfg, inv, num_pages)
    specs: List[StepSpec] = []

    # -- decode step: one signature, forever -------------------------------
    # the primary spec compiles the kernelized path (inv.backend); the
    # ``_reference`` twin keeps the jnp oracle lowering inventoried so its
    # gather/temp footprint stays visible next to the kernel's.
    decode_fn, decode_donate = steps_hot["decode_step"]
    decode_args = (
        params, caches, _sds((B, 1), jnp.int32), _sds((B,), jnp.int32),
        _sds((B, max_pages), jnp.int32), _sds((B,), jnp.bool_),
    )

    def _decode_args(_key):
        return (
            arena.params(), arena.fresh_caches(),
            jnp.zeros((B, 1), jnp.int32), jnp.zeros((B,), jnp.int32),
            jnp.zeros((B, max_pages), jnp.int32), jnp.zeros((B,), bool),
        )

    specs.append(StepSpec(
        name="decode_step",
        fn=decode_fn,
        args=decode_args,
        donate_argnums=decode_donate,
        probe=ProbeSet(keys=(0, 1), make_args=_decode_args,
                       expected_entries=1),
    ))
    specs.append(StepSpec(
        name="decode_step_reference",
        fn=steps["decode_step"][0],
        args=decode_args,
        donate_argnums=steps["decode_step"][1],
    ))

    # -- prefill chunk: one spec per shape in the closure -------------------
    chunk_fn, chunk_donate = steps["prefill_chunk"]
    for n in closure:
        specs.append(StepSpec(
            name=f"prefill_chunk_{n}",
            fn=chunk_fn,
            args=(
                params, caches, _sds((1, n), jnp.int32),
                _sds((), jnp.int32), _sds((), jnp.int32),
                _sds((n,), jnp.int32), _sds((n,), jnp.int32),
                _sds((max_pages,), jnp.int32), _sds((), jnp.int32),
            ),
            donate_argnums=chunk_donate,
        ))

    # the probe drives the *planned* chunk sequence for every probe prompt
    # through one fresh jit; entries must equal the distinct planned shapes
    planned = [n for plan in plans.values() for n in plan]

    def _chunk_args(n):
        return (
            arena.params(), arena.fresh_caches(),
            jnp.zeros((1, n), jnp.int32), jnp.int32(0), jnp.int32(0),
            jnp.zeros((n,), jnp.int32), jnp.zeros((n,), jnp.int32),
            jnp.zeros((max_pages,), jnp.int32), jnp.int32(0),
        )

    # attach the probe (and the static plan/closure pair) to the full-chunk
    # spec — the one signature every multi-chunk admission exercises
    full = next(s for s in specs if s.name == f"prefill_chunk_{chunk_size}")
    full.probe = ProbeSet(
        keys=tuple(planned), make_args=_chunk_args,
        expected_entries=len(set(planned)),
    )
    full.signature_plan = tuple(planned)
    full.signature_closure = closure

    # -- COW page copy: page ids are traced, one signature ------------------
    cow_fn, cow_donate = steps_hot["cow_copy"]
    cow_args = (caches, _sds((), jnp.int32), _sds((), jnp.int32))

    def _cow_args(key):
        return (arena.fresh_caches(), jnp.int32(1 + key), jnp.int32(2 + key))

    specs.append(StepSpec(
        name="cow_copy",
        fn=cow_fn,
        args=cow_args,
        donate_argnums=cow_donate,
        probe=ProbeSet(keys=(0, 1), make_args=_cow_args, expected_entries=1),
    ))
    specs.append(StepSpec(
        name="cow_copy_reference",
        fn=steps["cow_copy"][0],
        args=cow_args,
        donate_argnums=steps["cow_copy"][1],
    ))

    # -- unchunked install: one full-prefill source structure ---------------
    install_fn, install_donate = steps["install"]
    Sp = 2 * inv.page_size  # a bucketed two-page prompt
    _, src = jax.eval_shape(
        functools.partial(M.prefill, cfg),
        params, {"tokens": _sds((1, Sp), jnp.int32)}, _sds((), jnp.int32),
    )
    specs.append(StepSpec(
        name="install",
        fn=install_fn,
        args=(
            caches, src, _sds((), jnp.int32),
            _sds((Sp,), jnp.int32), _sds((Sp,), jnp.int32),
        ),
        donate_argnums=install_donate,
    ))

    # -- sharded inventory: attach the engine's real mesh shardings ---------
    if inv.mesh:
        _shard_specs(cfg, inv.mesh, params, caches, specs)

    return Inventory(
        cfg=cfg, geometry=inv, specs=specs, chunk_size=chunk_size,
        chunk_closure=closure, chunk_plans=plans,
    )


def _shard_specs(cfg, mesh_spec: str, params, caches, specs) -> None:
    """Turn the single-device specs into the mesh inventory, in place.

    Mirrors exactly what :class:`repro.serve.engine.Engine` builds on a
    mesh: resident-TP weights, adapter-registry pool placement, replicated
    host-fed inputs, explicit out shardings so donation composes — and the
    step bodies wrapped in :func:`repro.distributed.axes.traced_under` so
    activation constraints and the pallas shard_map dispatch see the policy
    at trace time.  The compiled artifacts are then the true partitioned
    modules RPJ101 (donation survives sharding) and RPJ106 (collective
    traffic) gate.
    """
    from repro.distributed import axes as AX
    from repro.distributed import sharding as SH
    from repro.launch.mesh import make_serve_mesh

    mesh = make_serve_mesh(mesh_spec)
    SH.validate_paged_sharding(cfg, mesh)
    param_sh, pool_sh, rep = SH.serve_shardings(cfg, mesh, params, caches)
    by_step = {
        "decode_step": (
            (param_sh, pool_sh, rep, rep, rep, rep), (rep, rep, pool_sh)
        ),
        "prefill_chunk": ((param_sh, pool_sh) + (rep,) * 7, (rep, pool_sh)),
        "cow_copy": ((pool_sh, rep, rep), pool_sh),
        "install": ((pool_sh, rep, rep, rep, rep), pool_sh),
    }
    for spec in specs:
        for prefix, (ins, outs) in by_step.items():
            if spec.name.startswith(prefix):
                spec.in_shardings, spec.out_shardings = ins, outs
                break
        spec.fn = AX.traced_under(mesh, spec.fn)

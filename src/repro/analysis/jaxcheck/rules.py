"""RPJ101–RPJ106: the compiled-artifact rules.

Each rule is ``rule(steps, inv, budgets) -> List[Finding]`` over the
compiled inventory (:class:`harness.CompiledStep`); waivers from the
budgets file suppress a rule per step (or globally).  All rules are pure
artifact inspection except the RPJ104 probes, which drive real smoke-sized
calls through a fresh jit to count compiled cache entries.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.analysis.jaxcheck import RULE_IDS, Budgets, Finding
from repro.analysis.jaxcheck.harness import (
    CompiledStep,
    collective_stats,
    convert_stats,
    gather_stats,
)
from repro.analysis.jaxcheck.inventory import Inventory


def rule_rpj101(steps, inv, budgets) -> List[Finding]:
    """Donation-effectiveness: every leaf of every ``donate_argnums``
    argument must appear in the executable's ``input_output_alias`` — a
    donated-but-unaliased buffer means XLA fell back to a copy and the
    in-place pool update the engine depends on silently stopped happening."""
    out = []
    for cs in steps:
        missing = sorted(cs.donated_params - cs.aliased_params)
        if not missing:
            continue
        labels = ", ".join(cs.donated_leaf_labels[i] for i in missing[:4])
        if len(missing) > 4:
            labels += f", ... ({len(missing) - 4} more)"
        out.append(Finding(
            "RPJ101", cs.name,
            f"donated buffer(s) not aliased to any output "
            f"(donation became a copy): {labels}",
        ))
    return out


def rule_rpj102(steps, inv, budgets) -> List[Finding]:
    """Materialized-gather: the largest ``gather`` output in a step's
    lowered jaxpr must stay under the step's ``max_gather_bytes`` budget —
    the 'whole K/V pool gathered into a dense buffer' hazard."""
    out = []
    for cs in steps:
        gathers = gather_stats(cs.jaxpr)
        if not gathers:
            continue
        biggest = max(g["output_bytes"] for g in gathers)
        budget = budgets.budget(cs.name, "max_gather_bytes")
        if budget is None:
            out.append(Finding(
                "RPJ102", cs.name,
                f"{len(gathers)} gather op(s) (largest output {biggest} B) "
                f"but no max_gather_bytes budget — run --write-budgets",
            ))
        elif not budgets.allowed(cs.name, "max_gather_bytes", biggest):
            out.append(Finding(
                "RPJ102", cs.name,
                f"gather output {biggest} B exceeds budget {budget} B "
                f"(+{budgets.tolerance:.0%} tolerance)",
            ))
    return out


def rule_rpj103(steps, inv, budgets) -> List[Finding]:
    """Dtype-promotion drift: no ``convert_element_type`` in a hot step may
    upcast past the planned widest dtype (``allowed_widest``) — a stray
    float64/int64 promotion doubles the bytes every downstream op moves."""
    widest = np.dtype(budgets.allowed_widest).itemsize
    out = []
    for cs in steps:
        seen = set()
        for c in convert_stats(cs.jaxpr):
            if c["to_itemsize"] <= widest:
                continue
            pair = (c["from"], c["to"])
            if pair in seen:
                continue
            seen.add(pair)
            out.append(Finding(
                "RPJ103", cs.name,
                f"upcast {c['from']} -> {c['to']} is wider than "
                f"allowed_widest={budgets.allowed_widest}",
            ))
    return out


def rule_rpj104(steps, inv, budgets) -> List[Finding]:
    """Retrace-closure: (a) statically, every chunk shape admission plans
    must lie inside the enumerated closure; (b) live, driving each step's
    probe calls through a fresh jit must compile exactly the declared
    number of cache entries — more means a weak-type/shape leak is minting
    unbounded jit signatures at serve time."""
    out = []
    for cs in steps:
        spec = cs.spec
        if spec.signature_plan is not None and spec.signature_closure is not None:
            escaped = sorted(set(spec.signature_plan) - set(spec.signature_closure))
            if escaped:
                out.append(Finding(
                    "RPJ104", cs.name,
                    f"planned chunk shape(s) {escaped} escape the "
                    f"enumerated closure {tuple(spec.signature_closure)}",
                ))
        if spec.probe is None:
            continue
        jitted = jax.jit(  # repro: noqa RPR003 -- one fresh jit per probed
            # step, by design: counting its cache entries IS the check
            spec.fn, donate_argnums=spec.donate_argnums
        )
        for key in spec.probe.keys:
            jitted(*spec.probe.make_args(key))
        entries = jitted._cache_size()
        if entries != spec.probe.expected_entries:
            out.append(Finding(
                "RPJ104", cs.name,
                f"{len(spec.probe.keys)} probe call(s) compiled {entries} "
                f"jit cache entries, expected {spec.probe.expected_entries} "
                f"(signature leak)",
            ))
    return out


def rule_rpj105(steps, inv, budgets) -> List[Finding]:
    """Memory-budget regression: ``compiled.memory_analysis()`` temp/
    argument/output bytes must stay within the checked-in budget (plus
    tolerance); a step with no budget at all must be baselined first."""
    from repro.analysis.jaxcheck import GATED_MEMORY_FIELDS

    out = []
    for cs in steps:
        for field in GATED_MEMORY_FIELDS:
            value = cs.memory.get(field)
            if value is None:
                continue  # backend doesn't report this field
            budget = budgets.budget(cs.name, field)
            if budget is None:
                out.append(Finding(
                    "RPJ105", cs.name,
                    f"no budget for {field} (measured {value} B) — "
                    f"run --write-budgets",
                ))
            elif not budgets.allowed(cs.name, field, value):
                out.append(Finding(
                    "RPJ105", cs.name,
                    f"{field} {value} B exceeds budget {budget} B "
                    f"(+{budgets.tolerance:.0%} tolerance)",
                ))
    return out


def rule_rpj106(steps, inv, budgets) -> List[Finding]:
    """Collective-traffic budget: the cross-device collectives GSPMD
    partitioned into a sharded step's compiled module (all-reduce /
    all-gather / reduce-scatter / all-to-all / collective-permute), summed
    by payload bytes, must stay under the step's ``collective_bytes``
    budget.  The hazard this pins down: a sharding change that silently
    all-gathers the head-sharded KV pool (or the weights) every decode
    step — per-step wire traffic invisible to every single-device check.
    Steps with no collectives (single-device inventories) pass without a
    budget."""
    out = []
    for cs in steps:
        colls = collective_stats(cs.artifact.hlo_text())
        if not colls:
            continue
        total = sum(c["output_bytes"] for c in colls)
        ops = {}
        for c in colls:
            ops[c["op"]] = ops.get(c["op"], 0) + 1
        kinds = ", ".join(f"{n}x {op}" for op, n in sorted(ops.items()))
        budget = budgets.budget(cs.name, "collective_bytes")
        if budget is None:
            out.append(Finding(
                "RPJ106", cs.name,
                f"{len(colls)} collective(s) ({kinds}) moving {total} B "
                f"but no collective_bytes budget — run --write-budgets",
            ))
        elif not budgets.allowed(cs.name, "collective_bytes", total):
            out.append(Finding(
                "RPJ106", cs.name,
                f"collective traffic {total} B ({kinds}) exceeds budget "
                f"{budget} B (+{budgets.tolerance:.0%} tolerance)",
            ))
    return out


RULES: Dict[str, Callable] = {
    "RPJ101": rule_rpj101,
    "RPJ102": rule_rpj102,
    "RPJ103": rule_rpj103,
    "RPJ104": rule_rpj104,
    "RPJ105": rule_rpj105,
    "RPJ106": rule_rpj106,
}
assert tuple(RULES) == RULE_IDS


def run_rules(
    steps: Sequence[CompiledStep],
    inv: Inventory,
    budgets: Budgets,
    select: Optional[Sequence[str]] = None,
) -> List[Finding]:
    """All (selected) rules over the compiled inventory, waivers applied."""
    selected = tuple(select) if select else RULE_IDS
    findings: List[Finding] = []
    for rule_id in selected:
        for f in RULES[rule_id](steps, inv, budgets):
            if not budgets.waived(f.rule, f.step):
                findings.append(f)
    return findings

# repro: noqa-file RPR005 -- CLI report generator: prints ARE the output
"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh) cell we report three roofline terms:

    compute    = FLOPs_global / (chips × 197 TFLOP/s)
    memory     = HBM_bytes_per_device / 819 GB/s
    collective = collective_bytes_per_device / 50 GB/s/link

Term sources — a deliberate hybrid:

* The **terms** come from the analytic calculator (`analysis/analytic.py`)
  whose formulas follow the exact sharding rules we lower with.  Reason:
  XLA's ``cost_analysis()`` counts while-loop bodies ONCE, so for a
  scan-over-61-layers model its FLOPs/bytes are ~L× low (we verified
  useful-compute ratios of 26-118× before switching).
* The **dry-run HLO** remains the ground truth for (a) which collectives
  are actually scheduled (op kinds + counts + per-iteration bytes), (b)
  per-device buffer sizes (memory_analysis: does it fit), and (c) the
  6ND-vs-HLO sanity diagnostic.

Usage:
  PYTHONPATH=src python -m repro.analysis.roofline --dryrun experiments/dryrun \
      --out experiments/roofline.md
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

import repro.configs as C
from repro.analysis.analytic import (
    MeshInfo,
    roofline_terms,
)

SHAPE_TOKENS = {  # tokens processed per step (global)
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,
    "long_500k": 1,
}


def model_flops(rec: Dict) -> float:
    n = rec["active_params"]
    toks = SHAPE_TOKENS[rec["shape"]]
    mult = 6 if rec["shape"] == "train_4k" else 2
    return mult * n * toks


def analyze_record(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    cfg = C.get_config(rec["arch"])
    mesh = MeshInfo.multi() if rec["mesh"] == "multi" else MeshInfo.single()
    accum = rec.get("accum_steps", 1)
    terms = roofline_terms(cfg, rec["shape"], mesh, accum)
    coll = rec.get("collectives", {})
    coll_bytes_hlo = sum(v for k, v in coll.items() if k != "count")
    mf = model_flops(rec)
    suggestion = {
        "compute": "cut redundant FLOPs (remat policy, fused kernels, "
                   "lower-precision matmuls)",
        "memory": "reduce HBM traffic: fewer weight re-streams (less accum / "
                  "bigger TP), fused BWMA blocks, fp8 weights",
        "collective": "reshard to cut FSDP gathers (more TP, less ZeRO), "
                      "overlap collectives with compute, int8 grad wire",
    }[terms["dominant"]]
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh")},
        "chips": rec["n_devices"],
        "accum": accum,
        "t_compute_s": terms["compute"],
        "t_memory_s": terms["memory"],
        "t_collective_s": terms["collective"],
        "dominant": terms["dominant"],
        "roofline_fraction": terms["roofline_fraction"],
        "roofline_fraction_serial": terms["roofline_fraction_serial"],
        "model_flops_6nd": mf,
        "flops_analytic": terms["flops_global"],
        "useful_ratio": mf / terms["flops_global"] if terms["flops_global"]
        else float("nan"),
        "hlo_flops_periter_dev": rec["flops"],
        "hlo_collective_kinds": {k: v for k, v in coll.items()
                                 if k != "count" and v},
        "hlo_collective_count": coll.get("count", 0),
        "hlo_collective_bytes_periter": coll_bytes_hlo,
        "mem_args_gib": rec["memory"].get("argument_size_in_bytes", 0) / 2**30,
        "mem_temp_gib": rec["memory"].get("temp_size_in_bytes", 0) / 2**30,
        "fits_hbm": (rec["memory"].get("argument_size_in_bytes", 0)
                     + rec["memory"].get("temp_size_in_bytes", 0)) < 16 * 2**30,
        "suggestion": suggestion,
    }


def load_all(dryrun_dir: str) -> List[Dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def markdown_table(rows: List[Dict]) -> str:
    hdr = (
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
        "| dominant | frac (ovl) | frac (serial) | HBM GiB/dev (args+temp) "
        "| fits 16G |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} "
            f"| {r['t_collective_s']:.2e} | **{r['dominant']}** "
            f"| {r['roofline_fraction']:.3f} | {r['roofline_fraction_serial']:.3f} "
            f"| {r['mem_args_gib']:.1f}+{r['mem_temp_gib']:.1f} "
            f"| {'y' if r['fits_hbm'] else 'NO'} |"
        )
    return hdr + "\n".join(lines) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline.md")
    ap.add_argument("--mesh", default=None, help="filter: single|multi")
    args = ap.parse_args()
    recs = load_all(args.dryrun)
    rows, skipped = [], []
    for rec in recs:
        if args.mesh and rec.get("mesh") != args.mesh:
            continue
        a = analyze_record(rec)
        if a:
            rows.append(a)
        elif rec.get("status") == "skipped":
            skipped.append(rec)
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    md = [
        "# Roofline analysis\n",
        "\nTerms from the analytic calculator (sharding-rule-exact); HLO "
        "evidence columns from the compiled dry-run.  v5e constants: "
        "197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.\n",
        f"\n{len(rows)} compiled cells, {len(skipped)} documented skips.\n\n",
        markdown_table(rows),
        "\n## Per-cell bottleneck notes\n",
    ]
    for r in rows:
        kinds = ", ".join(f"{k}:{v/2**20:.0f}MiB" for k, v in
                          r["hlo_collective_kinds"].items())
        md.append(
            f"- **{r['arch']} × {r['shape']} × {r['mesh']}** — "
            f"{r['dominant']}-bound (frac {r['roofline_fraction']:.3f}); "
            f"HLO schedule: {r['hlo_collective_count']} collectives/iter "
            f"({kinds or 'none'}); to improve: {r['suggestion']}\n"
        )
    if skipped:
        md.append("\n## Skipped cells\n")
        for s in skipped:
            md.append(
                f"- {s['arch']} × {s['shape']} × {s['mesh']}: {s['reason']}\n"
            )
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        f.write("".join(md))
    print(f"wrote {args.out}: {len(rows)} rows")
    for r in rows:
        print(
            f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:6s} "
            f"dom={r['dominant']:10s} frac={r['roofline_fraction']:.3f} "
            f"serial={r['roofline_fraction_serial']:.3f} "
            f"fits={'y' if r['fits_hbm'] else 'N'}"
        )


if __name__ == "__main__":
    main()

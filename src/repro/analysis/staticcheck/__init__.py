"""repro.analysis.staticcheck — repo-specific, jit-aware static lint pass.

The serving stack's performance invariants (buffer donation, no host syncs in
the hot loop, no retrace churn, family dispatch only through the adapter
registry) are invisible to generic linters.  This package checks them with
AST-based rules:

===========  ==================================================================
rule id      what it catches
===========  ==================================================================
``RPR001``   use-after-donation: a value passed at a ``donate_argnums``
             position of a jitted callable is read again before rebinding
``RPR002``   host sync (``np.asarray`` / ``.item()`` / ``float()`` / ``int()``
             / ``np.stack``) inside a function marked ``# repro: hot-loop``
``RPR003``   ``jax.jit`` / jitted-partial construction inside a loop
``RPR004``   comparison against a layer-family literal outside the adapter
             registry (``src/repro/models/adapters.py``)
``RPR005``   stray ``print`` / ``jax.debug.print`` / ``breakpoint()`` in
             ``src/``
``RPR006``   explicit device->host transfer (``jax.device_get`` /
             ``.block_until_ready()`` / ``np.array(...)``) inside a
             ``# repro: hot-loop`` function
``RPR007``   hard-coded device selection in the serving stack
             (``jax.devices()[0]`` / ``jax.local_devices()[i]`` /
             ``jax.device_put`` without a sharding under ``src/repro/serve``)
===========  ==================================================================

Suppression pragmas (trailing comments):

- ``# repro: noqa RPR002 -- justification``   suppress rule(s) on this line
- ``# repro: noqa``                           suppress all rules on this line
- ``# repro: noqa-file RPR004 -- why``        suppress rule(s) in this file
- ``# repro: hot-loop``                       mark the next/current ``def`` as
  a hot-loop function (enables RPR002 inside it)

CLI::

    python -m repro.analysis.staticcheck src tests benchmarks

Exit 0 when clean (modulo the checked-in ``staticcheck.baseline``), 1 on new
findings, 2 on usage errors.
"""

from __future__ import annotations

import dataclasses
import io
import re
import tokenize
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = [
    "Finding",
    "FilePragmas",
    "check_source",
    "check_paths",
    "iter_python_files",
    "load_baseline",
    "format_baseline",
    "RULE_IDS",
    "RULE_DOCS",
]

RULE_IDS = (
    "RPR001", "RPR002", "RPR003", "RPR004", "RPR005", "RPR006", "RPR007",
)

RULE_DOCS = {
    "RPR001": "use-after-donation: donated buffer read again before rebinding",
    "RPR002": "host sync inside a `# repro: hot-loop` function",
    "RPR003": "jax.jit / jitted-partial constructed inside a loop",
    "RPR004": "layer-family branch outside the adapter registry",
    "RPR005": "stray print / jax.debug.print / breakpoint() in src/",
    "RPR006": "explicit device->host transfer in a `# repro: hot-loop` function",
    "RPR007": "hard-coded device selection / unsharded device_put in serve/",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint finding, reported as ``path:line:col: RULE message``."""

    rule: str
    path: str
    line: int
    col: int
    message: str
    snippet: str = ""

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"

    def baseline_key(self) -> str:
        """Line-number-independent identity used by the baseline file."""
        return f"{self.rule}|{self.path}|{self.snippet.strip()}"


# ---------------------------------------------------------------------------
# Pragma parsing
# ---------------------------------------------------------------------------

_PRAGMA_RE = re.compile(
    r"#\s*repro:\s*(?P<kind>noqa-file|noqa|hot-loop)"
    r"(?P<rules>[ \tA-Z0-9,]*)"
    r"(?:--.*)?$"
)

_ALL_RULES = frozenset(RULE_IDS)


@dataclasses.dataclass
class FilePragmas:
    """Per-file pragma state extracted from comments via tokenize."""

    #: line -> rule ids suppressed on that line (``_ALL_RULES`` for bare noqa)
    line_noqa: Dict[int, Set[str]] = dataclasses.field(default_factory=dict)
    #: rule ids suppressed for the whole file
    file_noqa: Set[str] = dataclasses.field(default_factory=set)
    #: lines carrying a ``# repro: hot-loop`` marker
    hot_lines: Set[int] = dataclasses.field(default_factory=set)

    def suppressed(self, rule: str, line: int) -> bool:
        if rule in self.file_noqa:
            return True
        return rule in self.line_noqa.get(line, ())


def _parse_rule_list(text: str) -> Set[str]:
    rules = {t for t in re.split(r"[,\s]+", text.strip()) if t}
    unknown = rules - _ALL_RULES
    if unknown:
        raise ValueError(f"unknown rule id(s) in pragma: {sorted(unknown)}")
    return rules or set(_ALL_RULES)


def parse_pragmas(source: str, path: str = "<string>") -> FilePragmas:
    pragmas = FilePragmas()
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        comments = [t for t in tokens if t.type == tokenize.COMMENT]
    except (tokenize.TokenError, SyntaxError):  # pragma: no cover - defensive
        return pragmas
    for tok in comments:
        m = _PRAGMA_RE.match(tok.string)
        if not m:
            continue
        kind = m.group("kind")
        line = tok.start[0]
        if kind == "hot-loop":
            pragmas.hot_lines.add(line)
            continue
        try:
            rules = _parse_rule_list(m.group("rules"))
        except ValueError as e:
            raise ValueError(f"{path}:{line}: {e}") from None
        if kind == "noqa-file":
            pragmas.file_noqa |= rules
        else:
            pragmas.line_noqa.setdefault(line, set()).update(rules)
    return pragmas


# ---------------------------------------------------------------------------
# Running rules over sources / paths
# ---------------------------------------------------------------------------


def check_source(
    source: str,
    path: str = "<string>",
    rules: Optional[Sequence[str]] = None,
) -> List[Finding]:
    """Lint one source string; returns unsuppressed findings sorted by line."""
    import ast

    from . import rules as _rules

    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [
            Finding(
                rule="RPR000",
                path=path,
                line=e.lineno or 1,
                col=e.offset or 0,
                message=f"syntax error: {e.msg}",
            )
        ]
    pragmas = parse_pragmas(source, path)
    lines = source.splitlines()
    ctx = _rules.RuleContext(path=path, source_lines=lines, pragmas=pragmas)
    selected = rules if rules is not None else RULE_IDS
    findings: List[Finding] = []
    for rule_id in selected:
        for f in _rules.RULES[rule_id](tree, ctx):
            if not pragmas.suppressed(f.rule, f.line):
                findings.append(f)
    findings.sort(key=lambda f: (f.line, f.col, f.rule))
    return findings


_SKIP_DIRS = {".git", "__pycache__", ".ruff_cache", ".pytest_cache", "build"}


def iter_python_files(paths: Iterable[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_file() and path.suffix == ".py":
            out.append(path)
        elif path.is_dir():
            for f in sorted(path.rglob("*.py")):
                if not any(part in _SKIP_DIRS for part in f.parts):
                    out.append(f)
    return out


def check_paths(
    paths: Iterable[str], rules: Optional[Sequence[str]] = None
) -> List[Finding]:
    findings: List[Finding] = []
    for f in iter_python_files(paths):
        src = f.read_text(encoding="utf-8")
        findings.extend(check_source(src, path=str(f), rules=rules))
    return findings


# ---------------------------------------------------------------------------
# Baseline file
# ---------------------------------------------------------------------------


def load_baseline(path: Path) -> Set[str]:
    """Baseline entries are ``RULE|path|stripped-source-line`` lines."""
    entries: Set[str] = set()
    for raw in path.read_text(encoding="utf-8").splitlines():
        line = raw.strip()
        if line and not line.startswith("#"):
            entries.add(line)
    return entries


def format_baseline(findings: Sequence[Finding]) -> str:
    header = (
        "# staticcheck baseline — known findings tolerated by CI.\n"
        "# Regenerate with: python -m repro.analysis.staticcheck "
        "--write-baseline <paths>\n"
        "# One `RULE|path|stripped source line` entry per finding; prefer\n"
        "# fixing or pragma-ing findings over baselining them.\n"
    )
    body = "".join(
        f"{k}\n" for k in sorted({f.baseline_key() for f in findings})
    )
    return header + body


def split_by_baseline(
    findings: Sequence[Finding], baseline: Set[str]
) -> Tuple[List[Finding], List[Finding]]:
    """Return (new, baselined) findings."""
    new: List[Finding] = []
    old: List[Finding] = []
    for f in findings:
        (old if f.baseline_key() in baseline else new).append(f)
    return new, old

# repro: noqa-file RPR005 -- the linter CLI reports findings via print
"""CLI: python -m repro.analysis.staticcheck [paths...]

Exit codes: 0 clean (all findings fixed, pragma'd, or baselined), 1 new
findings, 2 usage error.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from . import (
    RULE_DOCS,
    RULE_IDS,
    check_paths,
    format_baseline,
    load_baseline,
    split_by_baseline,
)

DEFAULT_PATHS = ("src", "tests", "benchmarks")
DEFAULT_BASELINE = "staticcheck.baseline"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.staticcheck",
        description="Repo-specific jit-aware lint pass (rules RPR001-RPR006).",
    )
    ap.add_argument(
        "paths",
        nargs="*",
        default=list(DEFAULT_PATHS),
        help=f"files/directories to lint (default: {' '.join(DEFAULT_PATHS)})",
    )
    ap.add_argument(
        "--select",
        metavar="RULES",
        help="comma-separated rule ids to run (default: all)",
    )
    ap.add_argument(
        "--baseline",
        metavar="FILE",
        default=DEFAULT_BASELINE,
        help=f"baseline file of tolerated findings (default: {DEFAULT_BASELINE})",
    )
    ap.add_argument(
        "--no-baseline",
        action="store_true",
        help="ignore the baseline file; report every finding",
    )
    ap.add_argument(
        "--write-baseline",
        action="store_true",
        help="rewrite the baseline file from the current findings and exit 0",
    )
    ap.add_argument(
        "--list-rules", action="store_true", help="print the rule table and exit"
    )
    ap.add_argument(
        "--format",
        choices=("text", "json"),
        default="text",
        help="output format: human-readable text (default) or a JSON report",
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid in RULE_IDS:
            print(f"{rid}  {RULE_DOCS[rid]}")
        return 0

    rules = None
    if args.select:
        rules = tuple(r.strip() for r in args.select.split(",") if r.strip())
        unknown = set(rules) - set(RULE_IDS)
        if unknown:
            print(f"unknown rule id(s): {sorted(unknown)}", file=sys.stderr)
            return 2

    missing = [p for p in args.paths if not Path(p).exists()]
    if missing:
        print(f"no such path(s): {missing}", file=sys.stderr)
        return 2

    try:
        findings = check_paths(args.paths, rules=rules)
    except ValueError as e:  # malformed pragma
        print(str(e), file=sys.stderr)
        return 2

    baseline_path = Path(args.baseline)
    if args.write_baseline:
        baseline_path.write_text(format_baseline(findings), encoding="utf-8")
        print(f"wrote {len(findings)} finding(s) to {baseline_path}")
        return 0

    baseline = set()
    if not args.no_baseline and baseline_path.is_file():
        baseline = load_baseline(baseline_path)
    new, old = split_by_baseline(findings, baseline)

    if args.format == "json":
        report = {
            "tool": "staticcheck",
            "status": "findings" if new else "clean",
            "n_new": len(new),
            "n_baselined": len(old),
            "findings": [
                {
                    "rule": f.rule,
                    "path": f.path,
                    "line": f.line,
                    "col": f.col,
                    "message": f.message,
                }
                for f in new
            ],
        }
        print(json.dumps(report, indent=2))
        return 1 if new else 0

    for f in new:
        print(f.format())
    n_files = len({f.path for f in new})
    if new:
        print(
            f"\n{len(new)} new finding(s) in {n_files} file(s)"
            + (f" ({len(old)} baselined)" if old else "")
        )
        return 1
    suffix = f" ({len(old)} baselined finding(s))" if old else ""
    print(f"staticcheck: clean{suffix}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

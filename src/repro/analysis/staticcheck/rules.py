"""Rule implementations for repro.analysis.staticcheck.

Each rule is a callable ``(tree, ctx) -> List[Finding]`` registered in
``RULES``.  Rules are intentionally heuristic: they operate on names and call
shapes, not types, and favour the idioms actually used in this repo (module
factories returning ``jax.jit(..., donate_argnums=...)``, ``self._fn = ...``
bindings, single-statement donate-and-rebind).  Known blind spots are listed
per rule.
"""

from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from . import FilePragmas, Finding


@dataclasses.dataclass
class RuleContext:
    path: str
    source_lines: Sequence[str]
    pragmas: FilePragmas

    def snippet(self, line: int) -> str:
        if 0 < line <= len(self.source_lines):
            return self.source_lines[line - 1]
        return ""

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        return Finding(
            rule=rule,
            path=self.path,
            line=line,
            col=getattr(node, "col_offset", 0),
            message=message,
            snippet=self.snippet(line),
        )


# ---------------------------------------------------------------------------
# Shared AST helpers
# ---------------------------------------------------------------------------


def _dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain of plain names, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jit_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    return _dotted(node.func) in ("jax.jit", "jit")


def _attach_parents(tree: ast.AST) -> None:
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            child._rpr_parent = parent  # type: ignore[attr-defined]


def _parent(node: ast.AST) -> Optional[ast.AST]:
    return getattr(node, "_rpr_parent", None)


def _enclosing(node: ast.AST, kinds) -> Optional[ast.AST]:
    cur = _parent(node)
    while cur is not None and not isinstance(cur, kinds):
        cur = _parent(cur)
    return cur


def _flat_targets(targets: Sequence[ast.expr]) -> List[ast.expr]:
    out: List[ast.expr] = []
    stack = list(targets)
    while stack:
        t = stack.pop()
        if isinstance(t, (ast.Tuple, ast.List)):
            stack.extend(t.elts)
        elif isinstance(t, ast.Starred):
            stack.append(t.value)
        else:
            out.append(t)
    return out


# ---------------------------------------------------------------------------
# RPR001 — use-after-donation
# ---------------------------------------------------------------------------
#
# Resolution pipeline:
#   1. functions whose every `return` is a literal int/tuple-of-ints
#      (e.g. ``_donate_caches() -> (1,)``) become resolvable constants;
#   2. functions that ``return jax.jit(..., donate_argnums=<resolvable>)``
#      become *factories* carrying donate positions;
#   3. assignments binding a jit call or a factory call to a name or
#      ``self.attr`` propagate those positions to the binding;
#   4. every call through a binding (``self._decode(...)``), a direct factory
#      product (``_install_fn(cfg)(...)``), or an inline jit call donates the
#      argument expressions at the recorded positions.
#
# A donated Name/Attribute argument is safe when the *same statement* rebinds
# it (``x, y = f(x)``); otherwise the first textually-later access in the
# enclosing function decides: Store => safe rebind, Load => finding.
# Blind spots: donation through intermediate locals, cross-function flows,
# and loop-carried reads before the loop's rebind.


def _literal_int_tuple(node: ast.AST) -> Optional[Tuple[int, ...]]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        vals: List[int] = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int):
                vals.append(e.value)
            else:
                return None
        return tuple(vals)
    return None


def _collect_const_tuple_fns(tree: ast.AST) -> Dict[str, Tuple[int, ...]]:
    out: Dict[str, Tuple[int, ...]] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        returns = [n for n in ast.walk(node) if isinstance(n, ast.Return)]
        vals = {_literal_int_tuple(r.value) for r in returns if r.value}
        if len(vals) == 1 and None not in vals and returns:
            out[node.name] = vals.pop()
    return out


def _donate_positions(
    call: ast.Call, const_fns: Dict[str, Tuple[int, ...]]
) -> Optional[Tuple[int, ...]]:
    for kw in call.keywords:
        if kw.arg != "donate_argnums":
            continue
        lit = _literal_int_tuple(kw.value)
        if lit is not None:
            return lit
        if isinstance(kw.value, ast.Call):
            name = _dotted(kw.value.func)
            if name in const_fns:
                return const_fns[name]
        return None
    return None


def rule_rpr001(tree: ast.AST, ctx: RuleContext) -> List[Finding]:
    _attach_parents(tree)
    const_fns = _collect_const_tuple_fns(tree)

    # Factories: functions returning a donating jax.jit(...)
    factories: Dict[str, Tuple[int, ...]] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        for ret in ast.walk(node):
            if isinstance(ret, ast.Return) and _is_jit_call(ret.value):
                pos = _donate_positions(ret.value, const_fns)
                if pos:
                    factories[node.name] = pos

    # Bindings: `x = jax.jit(...)`, `x = factory(...)`, `self.a = <either>`
    name_bind: Dict[str, Tuple[int, ...]] = {}
    attr_bind: Dict[str, Tuple[int, ...]] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign) or not isinstance(node.value, ast.Call):
            continue
        pos: Optional[Tuple[int, ...]] = None
        if _is_jit_call(node.value):
            pos = _donate_positions(node.value, const_fns)
        else:
            fname = _dotted(node.value.func)
            if fname in factories:
                pos = factories[fname]
        if not pos:
            continue
        for tgt in _flat_targets(node.targets):
            if isinstance(tgt, ast.Name):
                name_bind[tgt.id] = pos
            elif isinstance(tgt, ast.Attribute):
                attr_bind[tgt.attr] = pos

    # Donating call sites
    findings: List[Finding] = []
    for call in ast.walk(tree):
        if not isinstance(call, ast.Call):
            continue
        pos: Optional[Tuple[int, ...]] = None
        desc = ""
        func = call.func
        if isinstance(func, ast.Name) and func.id in name_bind:
            pos, desc = name_bind[func.id], func.id
        elif isinstance(func, ast.Attribute) and func.attr in attr_bind:
            pos, desc = attr_bind[func.attr], func.attr
        elif isinstance(func, ast.Call):
            inner = _dotted(func.func)
            if inner in factories:
                pos, desc = factories[inner], f"{inner}(...)"
            elif _is_jit_call(func):
                pos = _donate_positions(func, const_fns)
                desc = "jax.jit(...)"
        if not pos:
            continue
        for p in pos:
            if p >= len(call.args):
                continue
            findings.extend(_check_donated_arg(call, call.args[p], p, desc, ctx))
    return findings


def _check_donated_arg(
    call: ast.Call, arg: ast.expr, pos: int, desc: str, ctx: RuleContext
) -> List[Finding]:
    key = _dotted(arg)
    if key is None:  # fresh temporary (e.g. jnp.asarray(...)): nothing to track
        return []
    stmt = _enclosing(call, ast.stmt) if not isinstance(call, ast.stmt) else call
    if stmt is None:
        return []
    # Same-statement rebind: `out, self.kv.data = self._decode(.., self.kv.data, ..)`
    if isinstance(stmt, ast.Assign):
        if any(_dotted(t) == key for t in _flat_targets(stmt.targets)):
            return []
    scope = _enclosing(call, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Module))
    if scope is None:
        return []
    stmt_end = getattr(stmt, "end_lineno", stmt.lineno)
    accesses = []
    for node in ast.walk(scope):
        if not isinstance(node, (ast.Name, ast.Attribute)):
            continue
        if node.lineno <= stmt_end or _dotted(node) != key:
            continue
        accesses.append(node)
    accesses.sort(key=lambda n: (n.lineno, n.col_offset))
    for node in accesses:
        is_store = isinstance(node.ctx, (ast.Store, ast.Del)) and not isinstance(
            _parent(node), ast.AugAssign
        )
        if is_store:
            return []  # rebound before any read
        return [
            ctx.finding(
                "RPR001",
                node,
                f"'{key}' may be donated to '{desc}' at line {call.lineno} "
                f"(donate_argnums position {pos}) and is read here before "
                "rebinding",
            )
        ]
    return []


# ---------------------------------------------------------------------------
# RPR002 — host sync in a `# repro: hot-loop` function
# ---------------------------------------------------------------------------

_SYNC_CALLS = {"np.asarray", "np.stack", "numpy.asarray", "numpy.stack"}
_SYNC_METHODS = {"item", "tolist", "__array__"}
_SYNC_BUILTINS = {"float", "int", "bool"}


def _hot_functions(tree: ast.AST, ctx: RuleContext) -> List[ast.FunctionDef]:
    hot: List[ast.FunctionDef] = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        first = min([node.lineno] + [d.lineno for d in node.decorator_list])
        if ctx.pragmas.hot_lines & {node.lineno, first, first - 1}:
            hot.append(node)
    return hot


def rule_rpr002(tree: ast.AST, ctx: RuleContext) -> List[Finding]:
    findings: List[Finding] = []
    for fn in _hot_functions(tree, ctx):
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            dotted = _dotted(node.func)
            if dotted in _SYNC_CALLS:
                findings.append(
                    ctx.finding(
                        "RPR002",
                        node,
                        f"`{dotted}` in hot-loop `{fn.name}` forces a "
                        "device->host sync; defer or pragma the sanctioned "
                        "sync point",
                    )
                )
            elif (
                isinstance(node.func, ast.Attribute)
                and node.func.attr in _SYNC_METHODS
            ):
                findings.append(
                    ctx.finding(
                        "RPR002",
                        node,
                        f"`.{node.func.attr}()` in hot-loop `{fn.name}` "
                        "forces a device->host sync",
                    )
                )
            elif (
                isinstance(node.func, ast.Name)
                and node.func.id in _SYNC_BUILTINS
                and node.args
                and not all(isinstance(a, ast.Constant) for a in node.args)
            ):
                findings.append(
                    ctx.finding(
                        "RPR002",
                        node,
                        f"`{node.func.id}(...)` in hot-loop `{fn.name}` "
                        "blocks on the device value; defer or pragma if this "
                        "sync is sanctioned",
                    )
                )
    return findings


# ---------------------------------------------------------------------------
# RPR003 — jax.jit constructed inside a loop
# ---------------------------------------------------------------------------

_LOOPS = (ast.For, ast.AsyncFor, ast.While)
_COMPS = (ast.ListComp, ast.SetComp, ast.DictComp, ast.GeneratorExp)


def rule_rpr003(tree: ast.AST, ctx: RuleContext) -> List[Finding]:
    _attach_parents(tree)
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not _is_jit_call(node):
            continue
        cur = _parent(node)
        container = None
        while cur is not None:
            if isinstance(cur, _LOOPS + _COMPS):
                container = cur
                break
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # a def inside a loop re-jits per iteration only when called;
                # stop at the function boundary — RPR003 targets direct
                # construction in the loop body.
                break
            cur = _parent(cur)
        if container is not None:
            kind = "comprehension" if isinstance(container, _COMPS) else "loop"
            findings.append(
                ctx.finding(
                    "RPR003",
                    node,
                    f"jax.jit constructed inside a {kind} (line "
                    f"{container.lineno}): each iteration re-traces; hoist "
                    "the jit or memoize the jitted callable",
                )
            )
    return findings


# ---------------------------------------------------------------------------
# RPR004 — family branch outside the adapter registry
# ---------------------------------------------------------------------------

FAMILY_LITERALS = {
    "dense",
    "moe",
    "ssm",
    "hybrid",
    "encdec",
    "vlm",
    "mla",
    "swa",
    "full",
}
_SUBJECT_HINTS = {"family", "fam", "attn_type", "kind"}
_REGISTRY_SUFFIX = ("src", "repro", "models", "adapters.py")


def _is_registry_file(path: str) -> bool:
    parts = Path(path).parts
    return parts[-len(_REGISTRY_SUFFIX):] == _REGISTRY_SUFFIX or parts[-3:] == (
        "repro",
        "models",
        "adapters.py",
    )


def _family_strings(node: ast.expr) -> Set[str]:
    vals: Set[str] = set()
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        vals.add(node.value)
    elif isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                vals.add(e.value)
    return vals & FAMILY_LITERALS


def rule_rpr004(tree: ast.AST, ctx: RuleContext) -> List[Finding]:
    if _is_registry_file(ctx.path):
        return []
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Compare):
            continue
        lits: Set[str] = set()
        for side in [node.left] + list(node.comparators):
            lits |= _family_strings(side)
        if not lits:
            continue
        subjects = {
            n.id for n in ast.walk(node) if isinstance(n, ast.Name)
        } | {n.attr for n in ast.walk(node) if isinstance(n, ast.Attribute)}
        if not (subjects & _SUBJECT_HINTS):
            continue
        findings.append(
            ctx.finding(
                "RPR004",
                node,
                f"branch on layer-family literal(s) {sorted(lits)} outside "
                "the adapter registry (src/repro/models/adapters.py); add a "
                "capability flag or adapter hook instead",
            )
        )
    return findings


# ---------------------------------------------------------------------------
# RPR005 — stray print / jax.debug.print / breakpoint in src/
# ---------------------------------------------------------------------------

_DEBUG_CALLS = {
    "print": "print()",
    "breakpoint": "breakpoint()",
    "jax.debug.print": "jax.debug.print()",
    "jax.debug.breakpoint": "jax.debug.breakpoint()",
}


def rule_rpr005(tree: ast.AST, ctx: RuleContext) -> List[Finding]:
    if "src" not in Path(ctx.path).parts:
        return []
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func)
        if dotted in _DEBUG_CALLS:
            findings.append(
                ctx.finding(
                    "RPR005",
                    node,
                    f"stray `{_DEBUG_CALLS[dotted]}` in src/; use logging or "
                    "pragma CLI entry points",
                )
            )
    return findings


# ---------------------------------------------------------------------------
# RPR006 — explicit device->host transfer in a `# repro: hot-loop` function
# ---------------------------------------------------------------------------
#
# RPR002 catches the *accidental* syncs (`.item()`, `float(x)`); this rule
# catches the spelled-out ones: `jax.device_get(x)`, `x.block_until_ready()`
# and `np.array(x)` each pull a device value to the host (or block until it
# lands) and serialize the dispatch pipeline when they sit inside a
# hot-loop function.  Sanctioned sync points carry a `# repro: noqa RPR006`
# pragma with the justification, same as RPR002.

_TRANSFER_CALLS = {"jax.device_get", "np.array", "numpy.array"}
_TRANSFER_METHODS = {"block_until_ready", "copy_to_host_async"}


def _is_host_literal(node: ast.AST) -> bool:
    """A value built purely from literals — no device array involved."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, (ast.List, ast.Tuple, ast.Set)):
        return all(_is_host_literal(e) for e in node.elts)
    if isinstance(node, ast.Dict):
        return all(
            k is not None and _is_host_literal(k) and _is_host_literal(v)
            for k, v in zip(node.keys, node.values)
        )
    return False


def rule_rpr006(tree: ast.AST, ctx: RuleContext) -> List[Finding]:
    findings: List[Finding] = []
    for fn in _hot_functions(tree, ctx):
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            dotted = _dotted(node.func)
            if dotted in _TRANSFER_CALLS:
                # np.array(LITERAL) builds a host constant — no device involved
                if node.args and all(_is_host_literal(a) for a in node.args):
                    continue
                findings.append(
                    ctx.finding(
                        "RPR006",
                        node,
                        f"`{dotted}(...)` in hot-loop `{fn.name}` transfers "
                        "a device value to host; defer the fetch or pragma "
                        "the sanctioned sync point",
                    )
                )
            elif (
                isinstance(node.func, ast.Attribute)
                and node.func.attr in _TRANSFER_METHODS
            ):
                findings.append(
                    ctx.finding(
                        "RPR006",
                        node,
                        f"`.{node.func.attr}()` in hot-loop `{fn.name}` "
                        "blocks the dispatch pipeline on device completion",
                    )
                )
    return findings


# ---------------------------------------------------------------------------
# RPR007 — hard-coded device selection in the serving stack
# ---------------------------------------------------------------------------
#
# The engine places buffers through the mesh/sharding registry
# (`repro.distributed.sharding`); code under `src/repro/serve` that indexes
# the global device list (`jax.devices()[0]`, `jax.local_devices()[i]`) or
# calls `jax.device_put(x)` with no sharding/device pins work to one chip and
# silently breaks the tensor-parallel path — on a mesh the buffer lands
# replicated on device 0 and every collective downstream degenerates.
# `jax.device_put(x, sharding)` (second positional arg or `device=`) is the
# sanctioned form and is not flagged.

_DEVICE_LIST_CALLS = {"jax.devices", "jax.local_devices"}


def _in_serve_tree(path: str) -> bool:
    parts = Path(path).parts
    return "src" in parts and "serve" in parts


def rule_rpr007(tree: ast.AST, ctx: RuleContext) -> List[Finding]:
    if not _in_serve_tree(ctx.path):
        return []
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if (
            isinstance(node, ast.Subscript)
            and isinstance(node.value, ast.Call)
            and _dotted(node.value.func) in _DEVICE_LIST_CALLS
        ):
            findings.append(
                ctx.finding(
                    "RPR007",
                    node,
                    f"`{_dotted(node.value.func)}()[...]` hard-codes a device "
                    "in the serving stack; place buffers through a "
                    "NamedSharding from repro.distributed.sharding",
                )
            )
        elif (
            isinstance(node, ast.Call)
            and _dotted(node.func) == "jax.device_put"
            and len(node.args) < 2
            and not any(kw.arg == "device" for kw in node.keywords)
        ):
            findings.append(
                ctx.finding(
                    "RPR007",
                    node,
                    "`jax.device_put` without a sharding defaults to the "
                    "first device; pass a NamedSharding so the placement "
                    "follows the mesh",
                )
            )
    return findings


RULES: Dict[str, Callable[[ast.AST, RuleContext], List[Finding]]] = {
    "RPR001": rule_rpr001,
    "RPR002": rule_rpr002,
    "RPR003": rule_rpr003,
    "RPR004": rule_rpr004,
    "RPR005": rule_rpr005,
    "RPR006": rule_rpr006,
    "RPR007": rule_rpr007,
}

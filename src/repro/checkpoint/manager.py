"""Checkpointing: async, atomic, mesh-independent (elastic restarts).

Layout (one directory per step)::

    <dir>/step_000123/
        META.json          # tree structure, shapes, dtypes, step
        leaf_00000.npy ... # one file per pytree leaf (row-major, full array)

Design choices for fault tolerance at scale:

* **atomic**: written to ``step_X.tmp`` then renamed — a crash mid-save never
  corrupts the latest checkpoint;
* **async**: the train loop hands off a host copy and keeps stepping; the
  writer thread owns the IO (``wait()`` joins before exit);
* **mesh-independent**: leaves are stored as *logical* (unsharded) arrays;
  ``restore`` device_puts onto whatever mesh/sharding the restarted job has,
  so a job can come back on fewer/more healthy nodes (elastic);
* **keep_last_k** bounds disk usage.

On a real multi-host cluster the host-gather becomes per-shard files keyed by
``device.process_index``; the single-process layout here keeps the same API.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, List, Optional, Tuple

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, keep_last_k: int = 3):
        self.directory = directory
        self.keep_last_k = keep_last_k
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save

    def save(self, step: int, tree: Any, *, blocking: bool = False) -> None:
        """Snapshot ``tree`` at ``step``.  Non-blocking by default."""
        self.wait()  # one outstanding save at a time
        leaves, treedef = jax.tree.flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]  # host copy now
        meta = {
            "step": int(step),
            "treedef": str(treedef),
            "n_leaves": len(leaves),
            "shapes": [list(x.shape) for x in host_leaves],
            "dtypes": [str(x.dtype) for x in host_leaves],
            "paths": [str(p) for p, _ in
                      jax.tree_util.tree_flatten_with_path(tree)[0]],
            "time": time.time(),
        }

        def write():
            try:
                final = os.path.join(self.directory, f"step_{step:08d}")
                tmp = final + ".tmp"
                os.makedirs(tmp, exist_ok=True)
                for i, arr in enumerate(host_leaves):
                    np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
                with open(os.path.join(tmp, "META.json"), "w") as f:
                    json.dump(meta, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            write()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint failed: {err!r}") from err

    def _gc(self) -> None:
        steps = self.available_steps()
        for s in steps[: -self.keep_last_k]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore

    def available_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.available_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        like: Any,
        *,
        step: Optional[int] = None,
        shardings: Any = None,
    ) -> Tuple[int, Any]:
        """Load into the structure of ``like``; optionally device_put with
        ``shardings`` (a matching pytree of NamedSharding) — this is where
        elastic resharding onto a different mesh happens."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "META.json")) as f:
            meta = json.load(f)
        leaves, treedef = jax.tree.flatten(like)
        if len(leaves) != meta["n_leaves"]:
            raise ValueError(
                f"checkpoint has {meta['n_leaves']} leaves, target {len(leaves)}"
            )
        loaded = [
            np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
            for i in range(meta["n_leaves"])
        ]
        tree = jax.tree.unflatten(treedef, loaded)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings
            )
        else:
            tree = jax.tree.map(
                lambda a, x: jax.numpy.asarray(a, dtype=x.dtype), tree,
                jax.tree.unflatten(treedef, leaves),
            )
        return step, tree

"""Architecture registry: ``--arch <id>`` resolves through here."""
from __future__ import annotations

from typing import Dict, List

from repro.configs import (
    deepseek_v3_671b,
    granite_moe_3b,
    h2o_danube3_4b,
    hymba_1_5b,
    mamba2_130m,
    minicpm_2b,
    qwen15_110b,
    qwen2_vl_72b,
    starcoder2_7b,
    whisper_tiny,
)
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

_MODULES = [
    qwen2_vl_72b,
    granite_moe_3b,
    deepseek_v3_671b,
    mamba2_130m,
    qwen15_110b,
    starcoder2_7b,
    minicpm_2b,
    h2o_danube3_4b,
    hymba_1_5b,
    whisper_tiny,
]

ARCHS: Dict[str, object] = {m.ARCH_ID: m for m in _MODULES}


def arch_ids() -> List[str]:
    return list(ARCHS.keys())


def get_config(arch: str, *, smoke: bool = False, **overrides) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    cfg = ARCHS[arch].smoke_config() if smoke else ARCHS[arch].full_config()
    if overrides:
        import dataclasses

        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


__all__ = [
    "ARCHS",
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "arch_ids",
    "get_config",
]

# repro: noqa-file RPR004 -- the family field is *defined* and validated
# here; everything downstream of configs must go through the registry
"""Unified model configuration for every assigned architecture family."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int

    # attention flavor
    attn_type: str = "full"  # full | swa | mla
    window: int = 4096  # SWA window (attn_type == "swa")
    rope_theta: float = 1e6
    mrope_sections: Tuple[int, ...] = ()  # qwen2-vl M-RoPE (t, h, w) half-dims
    qkv_bias: bool = False
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu | gelu
    tie_embeddings: bool = False
    use_rope: bool = True

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0  # deepseek-v3: first 3 layers are dense
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # MLA (deepseek-v3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # SSM (mamba2 / hymba)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_chunk: int = 128

    # hybrid (hymba): parallel attention + SSM heads per layer
    hybrid: bool = False

    # multi-token prediction (deepseek-v3)
    mtp_depth: int = 0

    # encoder-decoder (whisper)
    n_encoder_layers: int = 0
    encoder_seq: int = 1500  # whisper: 30 s of audio at 50 Hz after conv stub
    max_decoder_positions: int = 4096  # learned decoder pos-emb table size

    # modality frontend stub: "none" | "vision" | "audio"
    frontend: str = "none"
    n_frontend_tokens: int = 0  # vis/audio embedding positions per sample

    dtype: Any = jnp.bfloat16

    # attention q-chunking for memory (flash-style, pure XLA)
    q_chunk: int = 512

    # data-layout policy for linear layers (the paper's technique):
    # "xla" lets XLA pick layouts (production dry-run path);
    # "bwma"/"rwma" route matmuls through the Pallas kernels (small scale).
    gemm_backend: str = "xla"
    block: int = 128  # accelerator block (BWMA quantum) when using kernels

    # serving-engine paged-decode execution path (resolve_backend name):
    # "reference" reads pages through the jnp gather->attend oracle;
    # "pallas" streams pages through the fused paged-attention / paged-COW
    # kernels (compiled on TPU, interpret mode elsewhere).  Part of the
    # frozen config on purpose: every jitted step cache is keyed by it.
    decode_backend: str = "reference"

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a TP-friendly multiple (Megatron-style): even
        shards let GSPMD reduce over the vocab dim without all-gathers."""
        return -(-self.vocab_size // 256) * 256

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / sliding-window)."""
        return self.family in ("ssm", "hybrid") or self.attn_type == "swa"

    @property
    def qk_head_dim(self) -> int:
        if self.attn_type == "mla":
            return self.qk_nope_dim + self.qk_rope_dim
        return self.d_head

    def param_count(self) -> int:
        """Approximate total parameters (for 6ND roofline math)."""
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab_size
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm":
            dz = 2 * self.d_inner + 2 * self.ssm_ngroups * self.ssm_state + self.ssm_nheads
            per_layer = d * dz + self.d_inner * d + self.d_inner
        else:
            if self.attn_type == "mla":
                qdim = self.n_heads * self.qk_head_dim
                attn = (
                    (d * self.q_lora_rank + self.q_lora_rank * qdim)
                    + d * (self.kv_lora_rank + self.qk_rope_dim)
                    + self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                    + self.n_heads * self.v_head_dim * d
                )
            else:
                attn = d * self.n_heads * self.d_head + 2 * d * self.n_kv_heads * self.d_head
                attn += self.n_heads * self.d_head * d
            per_layer += attn
            if self.hybrid:
                dz = 2 * self.d_inner + 2 * self.ssm_ngroups * self.ssm_state + self.ssm_nheads
                per_layer += d * dz + self.d_inner * d
        n_moe_layers = 0
        if self.n_experts:
            n_moe_layers = self.n_layers - self.first_k_dense
            dense_layers = self.first_k_dense
        else:
            dense_layers = self.n_layers if self.family != "ssm" else 0
        ffn_dense = 3 * d * f if self.act == "silu" else 2 * d * f
        moe_ffn = self.n_experts * 3 * d * self.moe_d_ff + d * self.n_experts
        moe_ffn += self.n_shared_experts * 3 * d * self.moe_d_ff
        total = emb + L * per_layer + dense_layers * ffn_dense + n_moe_layers * moe_ffn
        if self.n_encoder_layers:
            enc = self.n_encoder_layers * (4 * d * d + ffn_dense)
            total += enc + self.n_layers * 2 * d * d  # cross-attention
        return int(total)

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: top-k + shared only)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        n_moe_layers = self.n_layers - self.first_k_dense
        all_experts = n_moe_layers * self.n_experts * 3 * d * self.moe_d_ff
        active = n_moe_layers * self.top_k * 3 * d * self.moe_d_ff
        return int(full - all_experts + active)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

"""DeepSeek-V3 671B. [arXiv:2412.19437; hf]

61L, d_model 7168, 128 heads, MLA (q_lora 1536, kv_lora 512, qk_nope 128,
qk_rope 64, v_head 128), first 3 layers dense (d_ff 18432), then MoE with
1 shared + 256 routed experts top-8 (d_ff 2048/expert), MTP depth 1,
vocab 129280.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "deepseek-v3-671b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_head=128,
        d_ff=18432, vocab_size=129280,
        attn_type="mla",
        q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        n_experts=256, n_shared_experts=1, top_k=8, moe_d_ff=2048,
        first_k_dense=3, mtp_depth=1, rope_theta=1e4, q_chunk=128,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, d_head=24,
        d_ff=96, vocab_size=256,
        attn_type="mla",
        q_lora_rank=32, kv_lora_rank=24, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16,
        n_experts=4, n_shared_experts=1, top_k=2, moe_d_ff=32,
        first_k_dense=1, mtp_depth=1, q_chunk=16,
    )

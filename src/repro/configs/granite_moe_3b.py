"""Granite-3.0 MoE 3B-A800M. [hf:ibm-granite; hf]

32L, d_model 1536, 24 heads (GQA kv=8), 40 experts top-8, d_ff 512/expert,
vocab 49155, tied embeddings.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "granite-moe-3b-a800m"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_head=64,
        d_ff=512, vocab_size=49155,
        n_experts=40, top_k=8, moe_d_ff=512,
        tie_embeddings=True, rope_theta=1e4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe",
        n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_head=16,
        d_ff=64, vocab_size=512,
        n_experts=8, top_k=2, moe_d_ff=64,
        tie_embeddings=True, q_chunk=16,
    )

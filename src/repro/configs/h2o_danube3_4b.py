"""H2O-Danube3-4B — llama/mistral mix with sliding-window attention.
[arXiv:2401.16818]

24L, d_model 3840, 32 heads (GQA kv=8), d_ff 10240, vocab 32000, SWA.
Sub-quadratic (window 4096): runs long_500k.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "h2o-danube-3-4b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, d_head=120,
        d_ff=10240, vocab_size=32000,
        attn_type="swa", window=4096, rope_theta=1e4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=96, n_heads=8, n_kv_heads=2, d_head=12,
        d_ff=192, vocab_size=512,
        attn_type="swa", window=8, q_chunk=16,
    )

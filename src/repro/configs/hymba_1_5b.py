"""Hymba-1.5B — hybrid parallel attention + Mamba heads. [arXiv:2411.13676; hf]

32L, d_model 1600, 25 heads (GQA kv=5), d_ff 5504, vocab 32001, ssm_state 16.
Each layer runs an SWA attention branch and an SSM branch in parallel on the
same normed input and fuses their outputs (mean), per the paper's
fused-parallel-heads design.  Sub-quadratic: runs long_500k.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "hymba-1.5b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_head=64,
        d_ff=5504, vocab_size=32001,
        attn_type="swa", window=1024,
        ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_headdim=64,
        ssm_ngroups=1, ssm_chunk=128, rope_theta=1e4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="hybrid",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=512,
        attn_type="swa", window=8,
        ssm_state=16, ssm_headdim=16, ssm_chunk=8, q_chunk=16,
    )

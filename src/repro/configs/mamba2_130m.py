"""Mamba-2 130M — SSD (state-space duality). [arXiv:2405.21060]

24L, d_model 768 (attention-free), ssm_state 128, headdim 64, expand 2
(d_inner 1536 -> 24 SSM heads), vocab 50280.  Sub-quadratic: runs long_500k.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "mamba2-130m"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="ssm",
        n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, d_head=0,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_conv=4, ssm_expand=2, ssm_headdim=64,
        ssm_ngroups=1, ssm_chunk=128, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, d_head=0,
        d_ff=0, vocab_size=512,
        ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_headdim=16,
        ssm_ngroups=1, ssm_chunk=8, tie_embeddings=True,
    )

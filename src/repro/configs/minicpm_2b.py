"""MiniCPM-2B — llama-like dense, trained with WSD schedule. [arXiv:2404.06395]

40L, d_model 2304, 36 heads (MHA: kv=36), d_ff 5760, vocab 122753, tied
embeddings.  The WSD (warmup-stable-decay) schedule lives in repro.optim.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "minicpm-2b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, d_head=64,
        d_ff=5760, vocab_size=122753,
        tie_embeddings=True, rope_theta=1e4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=96, n_heads=6, n_kv_heads=6, d_head=16,
        d_ff=192, vocab_size=512, tie_embeddings=True, q_chunk=16,
    )

"""Qwen1.5-110B — dense. [hf:Qwen; hf]

80L, d_model 8192, 64 heads (GQA kv=8), d_ff 49152, vocab 152064, QKV bias.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen1.5-110b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
        d_ff=49152, vocab_size=152064,
        qkv_bias=True, rope_theta=1e6, q_chunk=256,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_head=16,
        d_ff=384, vocab_size=512, qkv_bias=True, q_chunk=16,
    )

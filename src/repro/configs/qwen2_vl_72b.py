"""Qwen2-VL-72B — VLM backbone. [arXiv:2409.12191; hf]

80L, d_model 8192, 64 heads (GQA kv=8), d_ff 29568, vocab 152064.
M-RoPE (temporal/height/width sections) + QKV bias.  The vision frontend is a
stub per the assignment: ``input_specs`` supplies precomputed patch
embeddings occupying the sequence prefix.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen2-vl-72b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
        d_ff=29568, vocab_size=152064,
        qkv_bias=True, rope_theta=1e6,
        mrope_sections=(16, 24, 24),  # sums to d_head/2 = 64
        frontend="vision", n_frontend_tokens=1024, q_chunk=256,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_head=16,
        d_ff=256, vocab_size=512,
        qkv_bias=True, rope_theta=1e6, mrope_sections=(2, 3, 3),
        frontend="vision", n_frontend_tokens=8, q_chunk=16,
    )

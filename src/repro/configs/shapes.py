"""Assigned input shapes -> ShapeDtypeStruct stand-ins for the dry-run.

Every (arch × shape) cell is described by ``input_specs(cfg, shape)``:
no device allocation, weak-type-correct, shardable.  ``applicable`` encodes
the assignment's skip rules (long_500k only for sub-quadratic archs).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig
from repro.models import model as M


def applicable(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    """(runnable?, reason-if-not) for an (arch, shape) cell."""
    SHAPES[shape_name]  # validate the shape name (KeyError on a typo)
    if shape_name == "long_500k" and not cfg.is_subquadratic:
        return False, (
            "long_500k needs sub-quadratic attention; "
            f"{cfg.name} is pure full-attention (see DESIGN.md)"
        )
    return True, ""


def _token_spec(b: int, s: int):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def _frontend_extras(cfg: ModelConfig, b: int, s: int) -> Dict:
    out = {}
    if cfg.frontend == "vision":
        nv = min(cfg.n_frontend_tokens, s)
        out["vis_embeds"] = jax.ShapeDtypeStruct((b, nv, cfg.d_model), cfg.dtype)
        out["positions3"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
    if cfg.frontend == "audio":
        out["audio_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), cfg.dtype
        )
    return out


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    """Cache pytree as ShapeDtypeStructs (eval_shape: zero allocation)."""
    return jax.eval_shape(lambda: M.init_cache(cfg, batch, max_len))


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict:
    """Returns {kind, specs} where specs matches the step function's args."""
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {"tokens": _token_spec(b, s), "labels": _token_spec(b, s)}
        batch.update(_frontend_extras(cfg, b, s))
        return {"kind": "train", "batch": batch}
    if shape.kind == "prefill":
        batch = {"tokens": _token_spec(b, s)}
        batch.update(_frontend_extras(cfg, b, s))
        return {"kind": "prefill", "batch": batch}
    # decode: one new token against a cache of length s
    return {
        "kind": "decode",
        "tokens": _token_spec(b, 1),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "caches": cache_specs(cfg, b, s),
    }

"""StarCoder2-7B — dense code model. [arXiv:2402.19173; hf]

32L, d_model 4608, 36 heads (GQA kv=4), d_ff 18432, vocab 49152, LayerNorm +
GELU, RoPE, attention bias.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "starcoder2-7b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, d_head=128,
        d_ff=18432, vocab_size=49152,
        norm="layernorm", act="gelu", qkv_bias=True, rope_theta=1e5,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_head=16,
        d_ff=192, vocab_size=512,
        norm="layernorm", act="gelu", qkv_bias=True, q_chunk=16,
    )

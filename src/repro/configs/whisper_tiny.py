"""Whisper-tiny — encoder-decoder audio backbone. [arXiv:2212.04356]

4L encoder + 4L decoder, d_model 384, 6 heads, d_ff 1536, vocab 51865.
The conv frontend is a stub per the assignment: ``input_specs`` supplies
precomputed frame embeddings (B, 1500, 384).
"""
from repro.configs.base import ModelConfig

ARCH_ID = "whisper-tiny"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="encdec",
        n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_head=64,
        d_ff=1536, vocab_size=51865,
        norm="layernorm", act="gelu", use_rope=False,
        n_encoder_layers=4, encoder_seq=1500, frontend="audio",
        max_decoder_positions=32768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="encdec",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab_size=512,
        norm="layernorm", act="gelu", use_rope=False,
        n_encoder_layers=2, encoder_seq=32, frontend="audio",
        max_decoder_positions=64, q_chunk=16,
    )

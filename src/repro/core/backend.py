"""Selectable execution backends for the blocked (BWMA) encoder.

The paper separates *arrangement* (how matrices are laid out in memory) from
*execution* (the kernels that consume them).  This module does the same for
the repo: :class:`Backend` is the set of compute operators the encoder needs,
all closed over :class:`~repro.core.blockwise.Blocked` values, with two
implementations:

* ``"reference"`` — the pure-jnp blockwise operators from
  :mod:`repro.core.blockwise`.  Bit-for-bit the semantics the tests treat as
  the oracle; XLA fuses it however it likes.
* ``"pallas"`` — the Pallas kernels from :mod:`repro.kernels`: blocked GEMM,
  blocked softmax/layernorm, the fused GEMM+bias+GELU feed-forward, and the
  fused attention (scores -> softmax -> @V without materializing scores in
  HBM).  On TPU these compile natively; elsewhere they run with
  ``interpret=True`` so CPU CI exercises the identical BlockSpecs/grids.

Layout-neutral element-wise ops (add, bias, scale, map) are shared: they are
the paper's "Activation" category — no data movement depends on arrangement,
so there is nothing for a kernel backend to change (the FFN fusion handles
the one case where fusing them into a GEMM epilogue matters).

The protocol also carries the serving engine's paged-decode operators
(``paged_attention_decode``, ``mla_paged_attention_decode``,
``paged_copy_page``): the engine's KV pages are sized to ``cfg.block``, so
they are already kernel tiles — the reference backend reads them through
the jnp gather->attend oracle, the pallas backend streams them page-by-page
through the fused kernels in :mod:`repro.kernels.paged_attention`.  The
engine selects per :attr:`ModelConfig.decode_backend` via
:func:`resolve_backend`.

Select a backend by name or instance::

    from repro.core import backend as B
    be = B.resolve_backend("pallas")           # interpret=auto (CPU -> True)
    be = B.resolve_backend("pallas", interpret=True)
    be = B.resolve_backend(MyCustomBackend())
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Protocol, Union, runtime_checkable

import jax

try:  # moved out of experimental in newer jax
    from jax.shard_map import shard_map
except ImportError:  # pragma: no cover - version dependent
    from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as _P

from repro.core import blockwise as bw
from repro.core.blockwise import Blocked
from repro.kernels.bwma_attention import bwma_attention
from repro.kernels.bwma_fused_ffn import bwma_fused_ffn
from repro.kernels.bwma_gemm import bwma_gemm
from repro.kernels.bwma_layernorm import bwma_layernorm
from repro.kernels.bwma_softmax import bwma_softmax
from repro.kernels.bwma_transpose import bwma_transpose
from repro.kernels.paged_attention import (
    mla_paged_attention_decode,
    paged_attention_decode,
    paged_copy,
)


@runtime_checkable
class Backend(Protocol):
    """The operator set the blocked encoder dispatches through.

    All matrix arguments/results are :class:`Blocked`; blocked vectors
    (bias, gamma, beta) are raw ``(gn, bn)`` arrays as produced by
    :func:`repro.core.blockwise.block_vector`.  Implementations must accept
    leading batch/head dims on the data operands.
    """

    name: str

    def matmul(self, a: Blocked, b: Blocked) -> Blocked: ...

    def softmax(self, a: Blocked) -> Blocked: ...

    def layernorm(self, a: Blocked, gamma_b, beta_b) -> Blocked: ...

    def ffn(self, a: Blocked, w: Blocked, bias_b) -> Blocked: ...

    def attention(self, q: Blocked, k: Blocked, v: Blocked, *, scale) -> Blocked: ...

    def transpose(self, a: Blocked) -> Blocked: ...

    # -- serving-engine paged-decode operators (raw arrays, not Blocked:
    # -- the engine's pages already ARE kernel tiles — page size is
    # -- cfg.block — so there is no separate blocked arrangement step) --

    def paged_attention_decode(self, q, k_pages, v_pages, page_table,
                               seq_pos): ...

    def mla_paged_attention_decode(self, q_lat, q_rope, ckv_pages,
                                   krope_pages, page_table, seq_pos, *,
                                   scale): ...

    def paged_copy_page(self, pools: Dict, src, dst) -> Dict: ...

    # -- layout-neutral element-wise ops (shared implementations) --

    def add(self, a: Blocked, b: Blocked) -> Blocked: ...

    def bias(self, a: Blocked, bias_b) -> Blocked: ...

    def scale(self, a: Blocked, s) -> Blocked: ...

    def map(self, a: Blocked, fn: Callable) -> Blocked: ...


class _ElementwiseMixin:
    """The arrangement-independent ops, shared by every backend."""

    def add(self, a: Blocked, b: Blocked) -> Blocked:
        return bw.bw_add(a, b)

    def bias(self, a: Blocked, bias_b) -> Blocked:
        return bw.bw_bias(a, bias_b)

    def scale(self, a: Blocked, s) -> Blocked:
        return bw.bw_scale(a, s)

    def map(self, a: Blocked, fn: Callable) -> Blocked:
        return bw.bw_map(a, fn)


class ReferenceBackend(_ElementwiseMixin):
    """Pure-jnp blockwise semantics (the oracle path)."""

    name = "reference"

    def matmul(self, a: Blocked, b: Blocked) -> Blocked:
        return bw.bw_matmul(a, b)

    def softmax(self, a: Blocked) -> Blocked:
        return bw.bw_softmax(a)

    def layernorm(self, a: Blocked, gamma_b, beta_b) -> Blocked:
        return bw.bw_layernorm(a, gamma_b, beta_b)

    def ffn(self, a: Blocked, w: Blocked, bias_b) -> Blocked:
        return bw.bw_map(bw.bw_bias(bw.bw_matmul(a, w), bias_b), jax.nn.gelu)

    def attention(self, q: Blocked, k: Blocked, v: Blocked, *, scale) -> Blocked:
        return bw.bw_attention(q, k, v, scale=scale)

    def transpose(self, a: Blocked) -> Blocked:
        return bw.bw_transpose(a)

    # -- paged-decode operators: the jnp gather->attend oracle paths.
    # Lazy imports (models sits above core in the layering; the reference
    # math lives next to the cache layouts it reads, mirroring how
    # models.common.dense lazily resolves this module in the other
    # direction).

    def paged_attention_decode(self, q, k_pages, v_pages, page_table,
                               seq_pos):
        from repro.models import attention as attn

        return attn.paged_gather_attend(
            q, k_pages, v_pages, page_table, seq_pos
        )

    def mla_paged_attention_decode(self, q_lat, q_rope, ckv_pages,
                                   krope_pages, page_table, seq_pos, *,
                                   scale):
        from repro.models import attention as attn

        return attn.mla_paged_gather_attend(
            q_lat, q_rope, ckv_pages, krope_pages, page_table, seq_pos,
            scale=scale,
        )

    def paged_copy_page(self, pools: Dict, src, dst) -> Dict:
        from repro.models import attention as attn

        return attn.paged_copy_page(pools, src, dst)


class PallasBackend(_ElementwiseMixin):
    """The Pallas BWMA kernels — the execution path the paper describes.

    ``interpret=None`` auto-selects: compiled on TPU, interpreter elsewhere
    (bit-accurate, runs in CPU CI).
    """

    name = "pallas"

    def __init__(self, *, interpret: Optional[bool] = None):
        self._interpret = interpret
        ip = self.interpret
        # jit each operator once per backend instance: repeated shapes
        # (every layer of an encoder, every step of a sweep) reuse the
        # compiled/interpreted trace instead of re-tracing the pallas_call.
        self._matmul = jax.jit(functools.partial(bwma_gemm, interpret=ip))
        self._softmax = jax.jit(functools.partial(bwma_softmax, interpret=ip))
        self._layernorm = jax.jit(functools.partial(bwma_layernorm, interpret=ip))
        self._ffn = jax.jit(functools.partial(bwma_fused_ffn, interpret=ip))
        self._attention = jax.jit(
            functools.partial(bwma_attention, interpret=ip),
            static_argnames=("scale",),
        )
        self._transpose = jax.jit(functools.partial(bwma_transpose, interpret=ip))
        # the paged-decode kernels are deliberately NOT jitted here: they
        # trace inline inside the engine's already-jitted decode / COW
        # steps (a nested pjit would hazard the donation aliasing the
        # engine's in-place pool update depends on); standalone callers
        # (benchmarks, tests) jit them as needed
        self._paged_attention_decode = functools.partial(
            paged_attention_decode, interpret=ip
        )
        self._mla_paged_attention_decode = functools.partial(
            mla_paged_attention_decode, interpret=ip
        )
        self._paged_copy = functools.partial(paged_copy, interpret=ip)

    @property
    def interpret(self) -> bool:
        if self._interpret is None:
            return jax.default_backend() != "tpu"
        return self._interpret

    def matmul(self, a: Blocked, b: Blocked) -> Blocked:
        return self._matmul(a, b)

    def softmax(self, a: Blocked) -> Blocked:
        return self._softmax(a)

    def layernorm(self, a: Blocked, gamma_b, beta_b) -> Blocked:
        return self._layernorm(a, gamma_b, beta_b)

    def ffn(self, a: Blocked, w: Blocked, bias_b) -> Blocked:
        return self._ffn(a, w, bias_b)

    def attention(self, q: Blocked, k: Blocked, v: Blocked, *, scale) -> Blocked:
        return self._attention(q, k, v, scale=scale)

    def transpose(self, a: Blocked) -> Blocked:
        return self._transpose(a)

    # -- tensor-parallel dispatch: a pallas_call cannot be auto-partitioned
    # -- by GSPMD, so under an active TP shard policy (the engine's
    # -- mesh-traced steps install one) the paged kernels run PER SHARD via
    # -- shard_map with the head axis pre-partitioned.  Attention is
    # -- head-independent, so the per-shard online softmax is bit-identical
    # -- to the unsharded kernel on each head — the streamed pages never
    # -- cross devices and no collective is inserted here (only the
    # -- post-attention row-parallel projection all-reduces, outside).

    @staticmethod
    def _tp_policy(*head_counts):
        """The active TP policy when the head axes can shard, else None."""
        from repro.distributed import axes as AX

        pol = AX.current()
        if (pol is not None and pol.mesh is not None and pol.tp_size > 1
                and all(h % pol.tp_size == 0 for h in head_counts)):
            return pol
        return None

    def paged_attention_decode(self, q, k_pages, v_pages, page_table,
                               seq_pos):
        pol = self._tp_policy(q.shape[2], k_pages.shape[2])
        if pol is not None:
            tp = pol.tp_axis
            head = _P(None, None, tp, None)
            return shard_map(
                self._paged_attention_decode, mesh=pol.mesh,
                in_specs=(head, head, head, _P(), _P()),
                out_specs=head, check_rep=False,
            )(q, k_pages, v_pages, page_table, seq_pos)
        return self._paged_attention_decode(
            q, k_pages, v_pages, page_table, seq_pos
        )

    def mla_paged_attention_decode(self, q_lat, q_rope, ckv_pages,
                                   krope_pages, page_table, seq_pos, *,
                                   scale):
        pol = self._tp_policy(q_lat.shape[2])
        if pol is not None:
            # latent pages carry no head axis: they stay replicated and each
            # device attends its own query heads against the full pools
            head = _P(None, None, pol.tp_axis, None)
            return shard_map(
                functools.partial(
                    self._mla_paged_attention_decode, scale=scale
                ),
                mesh=pol.mesh,
                in_specs=(head, head, _P(), _P(), _P(), _P()),
                out_specs=head, check_rep=False,
            )(q_lat, q_rope, ckv_pages, krope_pages, page_table, seq_pos)
        return self._mla_paged_attention_decode(
            q_lat, q_rope, ckv_pages, krope_pages, page_table, seq_pos,
            scale=scale,
        )

    def paged_copy_page(self, pools: Dict, src, dst) -> Dict:
        out = {}
        for name, pool in pools.items():
            # stacked dense/GQA pools (L, pages, page, Hkv, dh) COW-copy per
            # head shard; headless pools (MLA latent) copy replicated
            pol = (self._tp_policy(pool.shape[3])
                   if pool.ndim == 5 else None)
            if pol is not None:
                spec = _P(None, None, None, pol.tp_axis, None)
                out[name] = shard_map(
                    self._paged_copy, mesh=pol.mesh,
                    in_specs=(spec, _P(), _P()),
                    out_specs=spec, check_rep=False,
                )(pool, src, dst)
            else:
                out[name] = self._paged_copy(pool, src, dst)
        return out


BACKENDS: Dict[str, Callable[..., Backend]] = {
    "reference": lambda **kw: ReferenceBackend(),
    "pallas": PallasBackend,
}

# Named backends are memoized: a PallasBackend's jit caches live on the
# instance, so handing out a fresh instance per resolve would retrace every
# kernel on every encoder/benchmark call.
_INSTANCES: Dict[tuple, Backend] = {}


def resolve_backend(
    spec: Union[str, Backend, None], *, interpret: Optional[bool] = None
) -> Backend:
    """Turn a backend name / instance / None into a Backend.

    ``None`` means ``"reference"``.  ``interpret`` only applies to backends
    that take it (the Pallas one) — passing it with any other backend is an
    error rather than a silent no-op.  Instances for a given resolved
    ``(name, interpret)`` are shared so their compilation caches persist.
    """
    if spec is None:
        spec = "reference"
    if isinstance(spec, str):
        try:
            factory = BACKENDS[spec]
        except KeyError:
            raise ValueError(
                f"unknown backend {spec!r}; available: {sorted(BACKENDS)}"
            ) from None
        takes_interpret = factory is PallasBackend
        if interpret is not None and not takes_interpret:
            raise ValueError(
                f"interpret={interpret!r} only applies to the 'pallas' "
                f"backend, not {spec!r}"
            )
        if takes_interpret:
            # normalize auto (None) to its resolved value so the auto and
            # explicit spellings share one instance (and one jit cache)
            resolved = interpret if interpret is not None else (
                jax.default_backend() != "tpu"
            )
            key = (spec, resolved)
            kw = {"interpret": resolved}
        else:
            key, kw = (spec, None), {}
        if key not in _INSTANCES:
            _INSTANCES[key] = factory(**kw)
        return _INSTANCES[key]
    if isinstance(spec, Backend):
        if interpret is not None:
            raise ValueError(
                "interpret= cannot override an already-constructed Backend"
            )
        return spec
    raise TypeError(f"backend must be a name or Backend, got {type(spec)}")

"""Block-wise (BWMA) operators, pure-jnp reference semantics.

These implement every operator a transformer encoder needs *directly on the
blocked layout* — the paper's key claim is that intermediates never need to be
rearranged back to row-major between layers (§3.2).  The Pallas kernels in
``repro.kernels`` are the accelerated versions of the GEMM-shaped ones; these
functions double as their oracles.

A :class:`Blocked` value carries the 4-D blocked data plus the logical
(unpadded) shape so padded rows/columns can be masked in the reductions
(softmax / layernorm) exactly as a real implementation must.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core.layout import BlockLayout, from_blockwise, to_blockwise


@dataclasses.dataclass(frozen=True)
class Blocked:
    """A logically (m, n) matrix stored block-wise as (gm, gn, bm, bn)."""

    data: jnp.ndarray  # (..., gm, gn, bm, bn)
    shape: Tuple[int, int]  # logical (m, n)
    layout: BlockLayout

    @property
    def dtype(self):
        return self.data.dtype

    def unblock(self) -> jnp.ndarray:
        return from_blockwise(self.data, self.layout, self.shape)


def tree_register():  # pragma: no cover - import-time side effect
    pass


jax.tree_util.register_pytree_node(
    Blocked,
    lambda b: ((b.data,), (b.shape, b.layout)),
    lambda aux, children: Blocked(children[0], aux[0], aux[1]),
)


def block(x: jnp.ndarray, layout: BlockLayout) -> Blocked:
    return Blocked(to_blockwise(x, layout), (x.shape[-2], x.shape[-1]), layout)


def _col_mask(b: Blocked) -> jnp.ndarray:
    """(gn, 1, bn) mask of valid (unpadded) logical columns."""
    gm, gn, bm, bn = b.data.shape[-4:]
    col = jnp.arange(gn * bn).reshape(gn, 1, bn)
    return col < b.shape[1]


def _row_mask(b: Blocked) -> jnp.ndarray:
    """(gm, bm, 1) mask of valid logical rows."""
    gm, gn, bm, bn = b.data.shape[-4:]
    row = jnp.arange(gm * bm).reshape(gm, bm, 1)
    return row < b.shape[0]


def bw_matmul(a: Blocked, b: Blocked, *, precision=None) -> Blocked:
    """Blocked GEMM: every (i, j, k) step is one accelerator-block matmul.

    K-padding is zeros so it contributes nothing to the accumulation.
    """
    if a.shape[1] != b.shape[0]:
        raise ValueError(f"inner dims mismatch: {a.shape} @ {b.shape}")
    out = jnp.einsum(
        "...mkab,...knbc->...mnac", a.data, b.data, precision=precision
    )
    return Blocked(out, (a.shape[0], b.shape[1]), a.layout)


def bw_add(a: Blocked, b: Blocked) -> Blocked:
    return Blocked(a.data + b.data, a.shape, a.layout)


def bw_bias(a: Blocked, bias_blocked: jnp.ndarray) -> Blocked:
    """bias_blocked: (gn, bn) — a bias vector stored block-wise."""
    gn, bn = bias_blocked.shape
    return Blocked(a.data + bias_blocked[None, :, None, :], a.shape, a.layout)


def bw_map(a: Blocked, fn: Callable[[jnp.ndarray], jnp.ndarray]) -> Blocked:
    """Element-wise op (paper's Activation case: layout-neutral)."""
    return Blocked(fn(a.data), a.shape, a.layout)


def bw_scale(a: Blocked, s) -> Blocked:
    return Blocked(a.data * s, a.shape, a.layout)


def bw_transpose(a: Blocked) -> Blocked:
    """Paper §3.2 Transpose: swap the block grid *and* each block's interior.

    In BWMA this is two nested small transposes with good locality (Fig. 5b);
    numerically it is exactly the logical transpose.
    """
    out = jnp.swapaxes(jnp.swapaxes(a.data, -4, -3), -2, -1)
    lo = BlockLayout(a.layout.bn, a.layout.bm)  # block interior swaps too
    return Blocked(out, (a.shape[1], a.shape[0]), lo)


def bw_softmax(a: Blocked, *, where_extra=None) -> Blocked:
    """Softmax over logical rows of a blocked matrix (paper §3.2 Softmax).

    The reduction runs over axes (gn, bn) — the blocked image of one row —
    with padded columns masked out.  Padded rows produce garbage that is
    cropped at unblock time; we keep them finite.
    """
    mask = _col_mask(a)  # (gn, 1, bn)
    if where_extra is not None:
        mask = jnp.logical_and(mask, where_extra)
    neg = jnp.finfo(a.dtype).min
    x = jnp.where(mask, a.data, neg)
    m = jnp.max(x, axis=(-3, -1), keepdims=True)
    e = jnp.exp(x - m)
    e = jnp.where(mask, e, 0.0)
    s = jnp.sum(e, axis=(-3, -1), keepdims=True)
    return Blocked(e / jnp.maximum(s, 1e-30), a.shape, a.layout)


def bw_layernorm(
    a: Blocked,
    gamma_blocked: jnp.ndarray,
    beta_blocked: jnp.ndarray,
    *,
    eps: float = 1e-5,
) -> Blocked:
    """Row-wise LayerNorm on the blocked layout (paper §3.2 Normalization).

    gamma/beta are stored block-wise as (gn, bn) so the whole op never leaves
    BWMA order.
    """
    mask = _col_mask(a)
    n = a.shape[1]
    x = jnp.where(mask, a.data, 0.0)
    mean = jnp.sum(x, axis=(-3, -1), keepdims=True) / n
    var = jnp.sum(jnp.where(mask, (a.data - mean) ** 2, 0.0), axis=(-3, -1), keepdims=True) / n
    y = (a.data - mean) * jax.lax.rsqrt(var + eps)
    y = y * gamma_blocked[None, :, None, :] + beta_blocked[None, :, None, :]
    y = jnp.where(mask, y, 0.0)
    return Blocked(y, a.shape, a.layout)


def bw_attention(q: Blocked, k: Blocked, v: Blocked, *, scale) -> Blocked:
    """Reference fused attention: softmax(q @ k^T * scale) @ v, blocked.

    Oracle for :func:`repro.kernels.bwma_attention.bwma_attention`; the
    score matrix is materialized here (it is the point of the kernel that
    it never is).
    """
    scores = bw_scale(bw_matmul(q, bw_transpose(k)), scale)
    return bw_matmul(bw_softmax(scores), v)


def add_head_axis(x: Blocked) -> Blocked:
    """Insert a broadcasting head axis before the 4 blocked dims."""
    return Blocked(x.data[..., None, :, :, :, :], x.shape, x.layout)


def merge_heads(ctx: Blocked) -> Blocked:
    """(..., h, gs, gd, b, b) per-head outputs -> (..., gs, h*gd, b, b).

    Stacks the heads along the column-grid axis.  When ``d_head`` is not a
    block multiple, each head keeps its zero padding *inside* the merged
    matrix, so the declared logical width is the block-quantized
    ``h * ceil(d_head / bn) * bn``; the output projection weight must be
    blocked per-head the same way (see ``encoder.block_layer_params``) so
    the interior zero columns meet zero rows and cancel in the GEMM.
    """
    s, dh = ctx.shape
    data = ctx.data
    h = data.shape[-5]
    dh_padded = data.shape[-3] * data.shape[-1]  # gd * bn
    data = jnp.moveaxis(data, -5, -4)  # (..., gs, h, gd, b, b)
    data = data.reshape(*data.shape[:-4], h * data.shape[-3], *data.shape[-2:])
    return Blocked(data, (s, h * dh_padded), ctx.layout)


def block_vector(v: jnp.ndarray, layout: BlockLayout) -> jnp.ndarray:
    """Store a length-N vector block-wise as (gn, bn) (zero padded)."""
    n = v.shape[-1]
    gn = -(-n // layout.bn)
    pad = gn * layout.bn - n
    if pad:
        v = jnp.pad(v, [(0, 0)] * (v.ndim - 1) + [(0, pad)])
    return v.reshape(*v.shape[:-1], gn, layout.bn)

"""End-to-end blocked transformer encoder — the paper's case study (BERT-base).

Demonstrates the paper's §3.2 claim: with BWMA, the *entire* encoder stack runs
on block-wise data; RWMA↔BWMA conversion happens once at the input and once at
the output.  Every intermediate (Q/K/V, attention scores, head outputs, FFN
activations) stays blocked.

Two functionally-identical paths are provided:

* ``encoder_rwma`` — conventional row-major jnp (the paper's baseline),
* ``encoder_bwma`` — everything blocked, dispatched through a selectable
  execution :class:`~repro.core.backend.Backend`:

  - ``backend="reference"`` — the pure-jnp blockwise operators,
  - ``backend="pallas"`` — the Pallas BWMA kernels (compiled on TPU,
    ``interpret=True`` elsewhere), including the fused attention and the
    fused GEMM+bias+GELU feed-forward.

All paths must agree to float tolerance (tested); the *performance*
difference is what ``repro.core.memmodel`` and the Pallas kernels quantify.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp

from repro.core import blockwise as bw
from repro.core.backend import Backend, resolve_backend
from repro.core.layout import BlockLayout, to_blockwise


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """BERT-style encoder. Paper defaults: BERT-base, seq 512."""

    seq_len: int = 512
    d_model: int = 768
    n_heads: int = 12
    d_head: int = 64
    d_ff: int = 3072
    n_layers: int = 12
    block: int = 16  # accelerator kernel size (paper: 8/16; TPU: 128)
    dtype: jnp.dtype = jnp.float32

    @property
    def layout(self) -> BlockLayout:
        return BlockLayout(self.block, self.block)


def init_layer_params(key, cfg: EncoderConfig) -> Dict[str, jnp.ndarray]:
    """One encoder layer's parameters, row-major (canonical storage)."""
    d, h, dh, f = cfg.d_model, cfg.n_heads, cfg.d_head, cfg.d_ff
    ks = jax.random.split(key, 8)
    s = 0.02
    return {
        "wq": jax.random.normal(ks[0], (h, d, dh), cfg.dtype) * s,
        "wk": jax.random.normal(ks[1], (h, d, dh), cfg.dtype) * s,
        "wv": jax.random.normal(ks[2], (h, d, dh), cfg.dtype) * s,
        "wo": jax.random.normal(ks[3], (h * dh, d), cfg.dtype) * s,
        "w1": jax.random.normal(ks[4], (d, f), cfg.dtype) * s,
        "b1": jnp.zeros((f,), cfg.dtype),
        "w2": jax.random.normal(ks[5], (f, d), cfg.dtype) * s,
        "b2": jnp.zeros((d,), cfg.dtype),
        "ln1_g": jnp.ones((d,), cfg.dtype),
        "ln1_b": jnp.zeros((d,), cfg.dtype),
        "ln2_g": jnp.ones((d,), cfg.dtype),
        "ln2_b": jnp.zeros((d,), cfg.dtype),
    }


def init_params(key, cfg: EncoderConfig) -> List[Dict[str, jnp.ndarray]]:
    return [init_layer_params(k, cfg) for k in jax.random.split(key, cfg.n_layers)]


# --------------------------------------------------------------------------
# RWMA baseline (row-major, conventional)
# --------------------------------------------------------------------------

def _layernorm(x, g, b, eps=1e-5):
    mean = jnp.mean(x, -1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, -1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * g + b


def encoder_layer_rwma(p, x, cfg: EncoderConfig):
    h = []
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.d_head, x.dtype))
    for i in range(cfg.n_heads):
        q = x @ p["wq"][i]
        k = x @ p["wk"][i]
        v = x @ p["wv"][i]
        a = jax.nn.softmax((q @ k.T) * scale, axis=-1)
        h.append(a @ v)
    att = jnp.concatenate(h, axis=-1) @ p["wo"]
    x = _layernorm(x + att, p["ln1_g"], p["ln1_b"])
    ff = jax.nn.gelu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]
    return _layernorm(x + ff, p["ln2_g"], p["ln2_b"])


def encoder_rwma(params, x, cfg: EncoderConfig):
    for p in params:
        x = encoder_layer_rwma(p, x, cfg)
    return x


# --------------------------------------------------------------------------
# BWMA path — everything blocked end-to-end
# --------------------------------------------------------------------------

def block_layer_params(p, cfg: EncoderConfig):
    """Pre-arrange one layer's weights block-wise (done once, offline).

    This is the paper's 'governed by the accelerator kernel size' step: the
    stored layout of every weight matrix is the accelerator block sequence.
    """
    lo = cfg.layout
    h, dh, d = cfg.n_heads, cfg.d_head, cfg.d_model
    out = {}
    for name in ("wq", "wk", "wv"):
        out[name] = to_blockwise(p[name], lo)  # (h, gm, gn, bm, bn)
    # wo is blocked PER HEAD along its row (h*dh) axis: each head's dh rows
    # are padded to a block multiple independently, so they line up with the
    # per-head padded columns that merge_heads stacks (interior zeros cancel
    # in the GEMM).  For dh % block == 0 this is bit-identical to blocking
    # the (h*dh, d) matrix directly.
    wo = to_blockwise(p["wo"].reshape(h, dh, d), lo)  # (h, gdh, gd, b, b)
    out["wo"] = wo.reshape(h * wo.shape[1], *wo.shape[2:])
    for name in ("w1", "w2"):
        out[name] = to_blockwise(p[name], lo)
    for name in ("b1", "b2", "ln1_g", "ln1_b", "ln2_g", "ln2_b"):
        out[name] = bw.block_vector(p[name], lo)
    return out


def block_params(params, cfg: EncoderConfig):
    return [block_layer_params(p, cfg) for p in params]


def encoder_layer_bwma(
    pb,
    xb: bw.Blocked,
    cfg: EncoderConfig,
    backend: Union[str, Backend, None] = None,
) -> bw.Blocked:
    lo = cfg.layout
    d, dh, f = cfg.d_model, cfg.d_head, cfg.d_ff
    be = resolve_backend(backend)
    scale = 1.0 / float(dh) ** 0.5  # static: kernels close over it
    # All heads at once: weights keep their (h, ...) leading dim, the input
    # gains a broadcasting head axis, and every op below runs as ONE batched
    # kernel call (vmap collapses the former per-head python loop).
    xh = bw.add_head_axis(xb)
    q = be.matmul(xh, bw.Blocked(pb["wq"], (d, dh), lo))  # (..., h, gs, gd, b, b)
    k = be.matmul(xh, bw.Blocked(pb["wk"], (d, dh), lo))
    v = be.matmul(xh, bw.Blocked(pb["wv"], (d, dh), lo))
    # Fused scores -> softmax -> @V: intermediates never leave BWMA order.
    ctx = be.attention(q, k, v, scale=scale)
    att_all = bw.merge_heads(ctx)  # (..., gs, h*gd, b, b)
    proj = be.matmul(att_all, bw.Blocked(pb["wo"], (att_all.shape[1], d), lo))
    x1 = be.layernorm(be.add(xb, proj), pb["ln1_g"], pb["ln1_b"])
    # Feed-forward up-projection: GEMM + bias + GELU fused at write-back.
    act = be.ffn(x1, bw.Blocked(pb["w1"], (d, f), lo), pb["b1"])
    down = be.bias(be.matmul(act, bw.Blocked(pb["w2"], (f, d), lo)), pb["b2"])
    return be.layernorm(be.add(x1, down), pb["ln2_g"], pb["ln2_b"])


def encoder_bwma(
    blocked_params,
    x,
    cfg: EncoderConfig,
    backend: Union[str, Backend, None] = None,
    *,
    interpret: Optional[bool] = None,
):
    """Full encoder: RWMA->BWMA once, N blocked layers, BWMA->RWMA once.

    ``backend`` selects the execution path ("reference" | "pallas" | a
    :class:`Backend` instance); ``interpret`` forces/disables Pallas
    interpreter mode (default: interpret everywhere but TPU).  ``x`` may
    carry leading batch dims: ``(..., seq_len, d_model)``.
    """
    be = resolve_backend(backend, interpret=interpret)
    xb = bw.block(x, cfg.layout)  # the only input-side conversion
    for pb in blocked_params:
        xb = encoder_layer_bwma(pb, xb, cfg, be)
    return xb.unblock()  # the only output-side conversion


def bert_base_config(block: int = 16, n_layers: int = 12) -> EncoderConfig:
    """The paper's evaluation model (§4.1): BERT-base, 512x768 input."""
    return EncoderConfig(
        seq_len=512, d_model=768, n_heads=12, d_head=64, d_ff=3072,
        n_layers=n_layers, block=block,
    )

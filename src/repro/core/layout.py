"""Block-wise memory arrangement (BWMA) layouts.

The paper's core object: a 2-D matrix stored in linear memory as a sequence of
accelerator-kernel-sized blocks instead of rows.  On TPU we realize this as a
4-D array ``(M/bm, N/bn, bm, bn)`` whose trailing two dims are one accelerator
block — any ``BlockSpec`` that picks ``(1, 1, bm, bn)`` then maps to a single
*contiguous* HBM region per grid step (the TPU analogue of the paper's
sequential DRAM bursts).

``RWMA`` is the conventional row-major 2-D array the paper compares against.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Tuple

import jax.numpy as jnp
import numpy as np


class LayoutPolicy(enum.Enum):
    """Which arrangement a model/layer uses for its matrices."""

    RWMA = "rwma"  # conventional row-major
    BWMA = "bwma"  # paper's block-wise arrangement (ours)


@dataclasses.dataclass(frozen=True)
class BlockLayout:
    """A block-wise layout governed by the accelerator kernel size.

    ``bm`` × ``bn`` is the accelerator block (paper: 8/16 PEs; TPU: multiples
    of (8, 128), default 128×128 to match the MXU).
    """

    bm: int = 128
    bn: int = 128

    def __post_init__(self):
        if self.bm <= 0 or self.bn <= 0:
            raise ValueError(f"block dims must be positive, got {self}")

    def padded_shape(self, shape: Tuple[int, int]) -> Tuple[int, int]:
        m, n = shape
        return (ceil_to(m, self.bm), ceil_to(n, self.bn))

    def grid(self, shape: Tuple[int, int]) -> Tuple[int, int]:
        pm, pn = self.padded_shape(shape)
        return (pm // self.bm, pn // self.bn)

    def blocked_shape(self, shape: Tuple[int, int]) -> Tuple[int, int, int, int]:
        gm, gn = self.grid(shape)
        return (gm, gn, self.bm, self.bn)


def ceil_to(x: int, q: int) -> int:
    return -(-x // q) * q


def pad2d(x: jnp.ndarray, layout: BlockLayout) -> jnp.ndarray:
    """Zero-pad the trailing two dims of ``x`` to block multiples."""
    m, n = x.shape[-2], x.shape[-1]
    pm, pn = layout.padded_shape((m, n))
    if (pm, pn) == (m, n):
        return x
    pad = [(0, 0)] * (x.ndim - 2) + [(0, pm - m), (0, pn - n)]
    return jnp.pad(x, pad)


def to_blockwise(x: jnp.ndarray, layout: BlockLayout) -> jnp.ndarray:
    """RWMA -> BWMA: ``(..., M, N) -> (..., M/bm, N/bn, bm, bn)``.

    The output's memory order (row-major over the 4-D shape) is exactly the
    paper's Fig. 4d: block after block, each block contiguous.
    """
    x = pad2d(x, layout)
    *lead, m, n = x.shape
    gm, gn = m // layout.bm, n // layout.bn
    x = x.reshape(*lead, gm, layout.bm, gn, layout.bn)
    # (..., gm, bm, gn, bn) -> (..., gm, gn, bm, bn)
    return jnp.swapaxes(x, -3, -2)


def from_blockwise(
    xb: jnp.ndarray, layout: BlockLayout, shape: Tuple[int, int]
) -> jnp.ndarray:
    """BWMA -> RWMA, cropping any block padding back to ``shape``."""
    *lead, gm, gn, bm, bn = xb.shape
    if (bm, bn) != (layout.bm, layout.bn):
        raise ValueError(f"array blocks {(bm, bn)} != layout {(layout.bm, layout.bn)}")
    x = jnp.swapaxes(xb, -3, -2).reshape(*lead, gm * bm, gn * bn)
    m, n = shape
    return x[..., :m, :n]


def blockwise_1d_view(xb: np.ndarray) -> np.ndarray:
    """The literal 1-D array as stored in memory (paper Fig. 4d). numpy-only,
    used by the memory model and tests to reason about addresses."""
    return np.ascontiguousarray(xb).reshape(-1)

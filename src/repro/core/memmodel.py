"""Trace-driven memory-hierarchy model reproducing the paper's evaluation.

The paper evaluates BWMA vs RWMA on a gem5-X simulated SoC (32 KB L1-D per
core, 1 MB shared L2, DRAM; CPU @ 2.3 GHz) with tightly-coupled accelerators
(SA8x8 / SA16x16 / SIMD16).  gem5 is not available here, so this module
rebuilds the *measurement instrument*: it generates the exact cache-line
access trace that tiled GEMM + the non-GEMM operators produce under each
memory arrangement, runs it through a cache simulator, and converts
hits/misses into cycles.

Everything is vectorized numpy — a full BERT-base encoder layer (the paper's
workload, 512x768, 12 heads) simulates in seconds.

Modeling choices (documented deviations from gem5):
  * caches are direct-mapped (vectorizable closed form); associativity shifts
    absolute miss counts but not the RWMA/BWMA ordering, which is driven by
    spatial locality.
  * a sequential next-line prefetcher is modeled as: an L1 miss whose line is
    the successor of the immediately preceding access is serviced at hit
    latency (the paper's §1 'contiguous block can simultaneously be
    pre-fetched').
  * DRAM sequential bursts: an L2-miss line contiguous with the previous
    L2-miss line pays the burst beat, not the full row-activate latency.
  * per-tile address-generation overhead: RWMA needs per-row-segment index
    arithmetic (the paper's Fig. 8 I-cache observation); BWMA needs one per
    block.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

LINE = 64  # bytes per cache line


# --------------------------------------------------------------------------
# Hardware descriptions (paper §4.1)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CacheConfig:
    l1_bytes: int = 32 * 1024          # 32 KB L1-D per core
    l2_bytes: int = 1024 * 1024        # 1 MB shared L2
    lat_l1: int = 2                    # cycles (paper §4.3)
    lat_l2: int = 20                   # cycles (paper §4.3)
    lat_dram: int = 120                # row miss
    lat_dram_burst: int = 30           # sequential beat
    prefetch: bool = True


@dataclasses.dataclass(frozen=True)
class AccelSpec:
    """An accelerator with kernel size T (paper: #PEs per row / SIMD lanes)."""

    name: str
    kernel: int                  # T — this is what BWMA blocks align to
    cycles_per_tile: int         # cycles for one TxTxT tile-GEMM step
    esize: int = 1               # bytes/element (TiC-SAT is int8)

    @staticmethod
    def sa(kernel: int, esize: int = 1) -> "AccelSpec":
        # weight-stationary systolic array: stream T rows + pipeline fill
        return AccelSpec(f"SA{kernel}x{kernel}", kernel, 3 * kernel, esize)

    @staticmethod
    def simd(kernel: int = 16, esize: int = 1) -> "AccelSpec":
        # T lanes x 1 MAC/cycle -> T^3 / T cycles per tile
        return AccelSpec(f"SIMD{kernel}", kernel, kernel * kernel, esize)


PAPER_ACCELERATORS = (AccelSpec.sa(8), AccelSpec.sa(16), AccelSpec.simd(16))


# --------------------------------------------------------------------------
# Trace generation: cache-line addresses in program order
# --------------------------------------------------------------------------

def _seg_lines(addr: np.ndarray, seg_bytes: int) -> np.ndarray:
    """Expand byte addresses of aligned segments into line numbers.

    addr: (...,) start byte addresses; returns (..., lps) line indices.
    Segments are assumed not to straddle lines unless seg_bytes >= LINE
    (true for all paper configs: T*esize in {8,16,32,64,...}).
    """
    lps = max(1, seg_bytes // LINE)
    return addr[..., None] // LINE + np.arange(lps, dtype=np.int64)


def gemm_trace(
    M: int, K: int, N: int, T: int, layout: str, esize: int,
    base_a: int, base_b: int, base_c: int,
) -> Tuple[np.ndarray, Dict[str, int]]:
    """Line trace of an output-stationary tiled GEMM A(MxK) @ B(KxN) -> C.

    Loop order (paper Fig. 3): for i, for j, for k: load A[i,k], B[k,j];
    after the k loop, write C[i,j].  Returns the interleaved line trace and
    per-matrix access/segment counts (for the address-generation overhead).
    """
    It, J, Kt = M // T, N // T, K // T
    ii = np.arange(It, dtype=np.int64)[:, None, None, None]
    jj = np.arange(J, dtype=np.int64)[None, :, None, None]
    kk = np.arange(Kt, dtype=np.int64)[None, None, :, None]
    rr = np.arange(T, dtype=np.int64)[None, None, None, :]
    zero = np.zeros((1, 1, 1, 1), dtype=np.int64)

    if layout == "rwma":
        # A tile (i,k): T row segments at stride K*esize
        a_addr = base_a + ((ii * T + rr) * K + kk * T) * esize
        b_addr = base_b + ((kk * T + rr) * N + jj * T) * esize
        a_lines = _seg_lines(np.broadcast_to(a_addr, (It, J, Kt, T)), T * esize)
        b_lines = _seg_lines(np.broadcast_to(b_addr, (It, J, Kt, T)), T * esize)
        a_lines = a_lines.reshape(It, J, Kt, -1)
        b_lines = b_lines.reshape(It, J, Kt, -1)
        c_addr = base_c + ((ii * T + rr) * N + jj * T) * esize
        c_lines = _seg_lines(
            np.broadcast_to(c_addr[:, :, 0, :], (It, J, T)), T * esize
        ).reshape(It, J, -1)
        segs_per_tile = T
    elif layout == "bwma":
        # A tile (i,k): one contiguous T*T block (paper Fig. 4d)
        a_addr = (base_a + (ii * Kt + kk) * (T * T) * esize) + zero
        b_addr = (base_b + (kk * J + jj) * (T * T) * esize) + zero
        a_lines = _seg_lines(
            np.broadcast_to(a_addr[..., 0], (It, J, Kt)), T * T * esize
        ).reshape(It, J, Kt, -1)
        b_lines = _seg_lines(
            np.broadcast_to(b_addr[..., 0], (It, J, Kt)), T * T * esize
        ).reshape(It, J, Kt, -1)
        c_addr = (base_c + (ii * J + jj) * (T * T) * esize) + zero
        c_lines = _seg_lines(
            np.broadcast_to(c_addr[:, :, 0, 0], (It, J)), T * T * esize
        ).reshape(It, J, -1)
        segs_per_tile = 1
    else:
        raise ValueError(layout)

    # interleave per (i,j,k): A lines then B lines; append C write per (i,j)
    step = np.concatenate([a_lines, b_lines], axis=-1)  # (It,J,Kt,L)
    per_ij = step.reshape(It, J, -1)
    per_ij = np.concatenate([per_ij, c_lines], axis=-1)
    trace = per_ij.reshape(-1)
    meta = {
        "tiles": It * J * Kt,
        "addr_segments": (2 * It * J * Kt + It * J) * segs_per_tile,
        "flops": 2 * M * K * N,
    }
    return trace, meta


def rowwise_trace(
    M: int, N: int, T: int, layout: str, esize: int, base: int, passes: int = 1
) -> np.ndarray:
    """Softmax / LayerNorm access pattern (paper Fig. 5a): read each logical
    row, write it back.  ``passes`` models multi-pass ops (softmax: max, exp,
    normalize -> effectively ~2 read passes + 1 write)."""
    rows = np.arange(M, dtype=np.int64)[:, None]
    if layout == "rwma":
        addr = base + (rows * N + np.arange(0, N, max(1, LINE // esize))) * esize
        lines = addr // LINE
    else:
        jb = np.arange(N // T, dtype=np.int64)[None, :]
        blk = (rows // T) * (N // T) + jb
        addr = base + (blk * T * T + (rows % T) * T) * esize
        lines = _seg_lines(addr, T * esize).reshape(M, -1)
    one_pass = lines.reshape(-1)
    return np.concatenate([one_pass] * (passes + 1))  # reads + write-back


def transpose_trace(
    M: int, N: int, T: int, layout: str, esize: int, base_in: int, base_out: int
) -> np.ndarray:
    """Transpose (paper Fig. 5b): gather input column-wise, write sequential."""
    cols = np.arange(N, dtype=np.int64)[:, None]
    rows = np.arange(M, dtype=np.int64)[None, :]
    if layout == "rwma":
        read = (base_in + (rows * N + cols) * esize) // LINE  # (N, M) one line/elt
    else:
        ib = np.arange(M // T, dtype=np.int64)[None, :]
        blk = ib * (N // T) + cols // T
        addr = base_in + (blk * T * T + cols % T) * esize  # column within block
        read = _seg_lines(addr, T * T * esize).reshape(N, -1)
    write = rowwise_trace(N, M, T, layout, esize, base_out, passes=0)
    return np.concatenate([read.reshape(-1), write])


# --------------------------------------------------------------------------
# Cache simulation (vectorized direct-mapped + sequential prefetch)
# --------------------------------------------------------------------------

def _dm_miss(lines: np.ndarray, cache_bytes: int) -> np.ndarray:
    """Direct-mapped miss vector in O(n log n), fully vectorized."""
    if len(lines) == 0:
        return np.zeros(0, dtype=bool)
    nsets = cache_bytes // LINE
    sets = lines % nsets
    tags = lines // nsets
    t = np.arange(len(lines))
    order = np.lexsort((t, sets))
    s_sorted, tag_sorted = sets[order], tags[order]
    same_set = np.zeros(len(lines), dtype=bool)
    same_set[1:] = s_sorted[1:] == s_sorted[:-1]
    same_tag = np.zeros(len(lines), dtype=bool)
    same_tag[1:] = tag_sorted[1:] == tag_sorted[:-1]
    miss_sorted = ~(same_set & same_tag)
    miss = np.empty(len(lines), dtype=bool)
    miss[order] = miss_sorted
    return miss


def _sequential(lines: np.ndarray) -> np.ndarray:
    """True where the access continues the previous line (prefetchable)."""
    seq = np.zeros(len(lines), dtype=bool)
    if len(lines) > 1:
        d = lines[1:] - lines[:-1]
        seq[1:] = (d == 1) | (d == 0)
    return seq


@dataclasses.dataclass
class MemStats:
    l1_accesses: int = 0
    l1_misses: int = 0
    l2_accesses: int = 0
    l2_misses: int = 0
    dram_accesses: int = 0
    mem_cycles: int = 0
    compute_cycles: int = 0
    addr_cycles: int = 0

    @property
    def cycles(self) -> int:
        # accelerator compute overlaps poorly with strided fetches in the
        # tightly-coupled design: total = memory + compute + address gen.
        return self.mem_cycles + self.compute_cycles + self.addr_cycles

    def add(self, o: "MemStats") -> "MemStats":
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(o, f.name))
        return self


def simulate_trace(lines: np.ndarray, cache: CacheConfig) -> MemStats:
    st = MemStats()
    st.l1_accesses = len(lines)
    l1_miss = _dm_miss(lines, cache.l1_bytes)
    if cache.prefetch:
        covered = _sequential(lines) & l1_miss
        demand_miss = l1_miss & ~covered
    else:
        covered = np.zeros_like(l1_miss)
        demand_miss = l1_miss
    st.l1_misses = int(demand_miss.sum())
    # L2 sees demand misses and prefetch fills
    l2_stream = lines[l1_miss]
    st.l2_accesses = len(l2_stream)
    l2_miss = _dm_miss(l2_stream, cache.l2_bytes)
    st.l2_misses = int(l2_miss.sum())
    dram_lines = l2_stream[l2_miss]
    st.dram_accesses = len(dram_lines)
    burst = _sequential(dram_lines)
    dram_cycles = int(
        (~burst).sum() * cache.lat_dram + burst.sum() * cache.lat_dram_burst
    )
    # prefetched lines are fetched ahead -> hit latency at use time; demand
    # misses pay L2 or DRAM latency.
    # approximate: fraction of demand misses that also miss L2
    frac_dram = st.l2_misses / max(st.l2_accesses, 1)
    n_demand_dram = int(round(st.l1_misses * frac_dram))
    st.mem_cycles = (
        (st.l1_accesses - st.l1_misses) * cache.lat_l1
        + (st.l1_misses - n_demand_dram) * cache.lat_l2
        + dram_cycles
    )
    return st


# --------------------------------------------------------------------------
# BERT encoder-layer workload (paper §4.1)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    seq: int = 512
    d_model: int = 768
    n_heads: int = 12
    d_head: int = 64
    d_ff: int = 3072


def _bases(n: int, stride: int = 1 << 22) -> List[int]:
    """Distinct, page-aligned base addresses for each tensor."""
    return [i * stride for i in range(n)]


def bert_layer_components(
    wl: WorkloadConfig, accel: AccelSpec, layout: str
) -> List[Tuple[str, np.ndarray, Dict[str, int]]]:
    """(name, trace, meta) for every component of one encoder layer."""
    T, es = accel.kernel, accel.esize
    S, D, H, Dh, F = wl.seq, wl.d_model, wl.n_heads, wl.d_head, wl.d_ff
    out: List[Tuple[str, np.ndarray, Dict[str, int]]] = []
    b = iter(_bases(64))

    def gemm(name, M, K, N, reps=1):
        tr, meta = gemm_trace(M, K, N, T, layout, es, next(b), next(b), next(b))
        if reps > 1:
            tr = np.concatenate([tr] * reps)
            meta = {k: v * reps for k, v in meta.items()}
        out.append((name, tr, meta))

    # per paper Fig. 1b / Fig. 7 components (all heads aggregated):
    gemm("qkv_gemm", S, D, Dh, reps=3 * H)
    out.append((
        "transpose",
        np.concatenate([
            transpose_trace(S, Dh, T, layout, es, next(b), next(b))
            for _ in range(H)
        ]),
        {"tiles": 0, "addr_segments": H * S, "flops": 0,
         "cpu_cycles": CPU_CYC_TRANSPOSE * H * S * Dh},
    ))
    gemm("qk_gemm", S, Dh, S, reps=H)
    out.append((
        "softmax",
        np.concatenate([
            rowwise_trace(S, S, T, layout, es, next(b), passes=2) for _ in range(H)
        ]),
        {"tiles": 0, "addr_segments": H * S, "flops": 5 * H * S * S,
         "cpu_cycles": CPU_CYC_SOFTMAX * H * S * S},
    ))
    gemm("av_gemm", S, S, Dh, reps=H)
    gemm("proj_gemm", S, H * Dh, D)
    out.append((
        "addnorm1",
        rowwise_trace(S, D, T, layout, es, next(b), passes=2),
        {"tiles": 0, "addr_segments": S, "flops": 8 * S * D,
         "cpu_cycles": CPU_CYC_NORM * S * D},
    ))
    gemm("ffn1_gemm", S, D, F)  # activation fused at write-back (paper §3.2)
    gemm("ffn2_gemm", S, F, D)
    out.append((
        "addnorm2",
        rowwise_trace(S, D, T, layout, es, next(b), passes=2),
        {"tiles": 0, "addr_segments": S, "flops": 8 * S * D,
         "cpu_cycles": CPU_CYC_NORM * S * D},
    ))
    return out


# scalar-CPU cycles per element for non-GEMM ops (exp / rsqrt are not
# accelerated in TiC-SAT; they run on the ARM core).  Calibrated so the
# BWMA non-GEMM share lands near the paper's 13.5 % (Fig. 7b).
CPU_CYC_SOFTMAX = 7
CPU_CYC_NORM = 6
CPU_CYC_TRANSPOSE = 1


ADDR_CYCLES_PER_SEGMENT = 4  # index arithmetic per fetched segment (RWMA pays
                             # this per row-segment, BWMA once per block)


def simulate_component(
    trace: np.ndarray, meta: Dict[str, int], accel: AccelSpec, cache: CacheConfig
) -> MemStats:
    st = simulate_trace(trace, cache)
    st.compute_cycles = (
        meta["tiles"] * accel.cycles_per_tile + meta.get("cpu_cycles", 0)
    )
    st.addr_cycles = meta["addr_segments"] * ADDR_CYCLES_PER_SEGMENT
    return st


def _interleave(arrays: Sequence[np.ndarray]) -> np.ndarray:
    """Proportional shuffle-merge of per-core streams (shared-L2 contention)."""
    arrays = [a for a in arrays if len(a)]
    if not arrays:
        return np.zeros(0, dtype=np.int64)
    pos = np.concatenate(
        [np.arange(len(a), dtype=np.float64) / max(len(a), 1) for a in arrays]
    )
    vals = np.concatenate(arrays)
    return vals[np.argsort(pos, kind="stable")]


def simulate_layer(
    wl: WorkloadConfig,
    accel: AccelSpec,
    layout: str,
    cores: int = 1,
    cache: Optional[CacheConfig] = None,
) -> Dict[str, MemStats]:
    """Simulate one encoder layer; returns per-component and 'total' stats.

    Multi-core: each component's outer loop is split across ``cores``; each
    core has a private L1, the L2 stream is the interleaved per-core miss
    streams (shared 1 MB L2), and wall-cycles divide the parallel work.
    """
    cache = cache or CacheConfig()
    results: Dict[str, MemStats] = {}
    total = MemStats()
    for name, trace, meta in bert_layer_components(wl, accel, layout):
        if cores == 1:
            st = simulate_component(trace, meta, accel, cache)
        else:
            chunks = np.array_split(trace, cores)
            per_core = []
            miss_streams = []
            for ch in chunks:
                l1_miss = _dm_miss(ch, cache.l1_bytes)
                if cache.prefetch:
                    covered = _sequential(ch) & l1_miss
                    demand = l1_miss & ~covered
                else:
                    demand = l1_miss
                per_core.append((len(ch), int(demand.sum()), int(l1_miss.sum())))
                miss_streams.append(ch[l1_miss])
            l2_stream = _interleave(miss_streams)
            l2_miss = _dm_miss(l2_stream, cache.l2_bytes)
            dram_lines = l2_stream[l2_miss]
            burst = _sequential(dram_lines)
            st = MemStats()
            st.l1_accesses = sum(c[0] for c in per_core)
            st.l1_misses = sum(c[1] for c in per_core)
            st.l2_accesses = len(l2_stream)
            st.l2_misses = int(l2_miss.sum())
            st.dram_accesses = len(dram_lines)
            frac_dram = st.l2_misses / max(st.l2_accesses, 1)
            n_demand_dram = int(round(st.l1_misses * frac_dram))
            dram_cycles = int(
                (~burst).sum() * cache.lat_dram + burst.sum() * cache.lat_dram_burst
            )
            # wall clock: parallel across cores
            st.mem_cycles = (
                (st.l1_accesses - st.l1_misses) * cache.lat_l1
                + (st.l1_misses - n_demand_dram) * cache.lat_l2
                + dram_cycles
            ) // cores
            st.compute_cycles = (
                meta["tiles"] * accel.cycles_per_tile + meta.get("cpu_cycles", 0)
            ) // cores
            st.addr_cycles = meta["addr_segments"] * ADDR_CYCLES_PER_SEGMENT // cores
        results[name] = st
        total.add(st)
    results["total"] = total
    return results


GEMM_COMPONENTS = (
    "qkv_gemm", "qk_gemm", "av_gemm", "proj_gemm", "ffn1_gemm", "ffn2_gemm",
)
NON_GEMM_COMPONENTS = ("transpose", "softmax", "addnorm1", "addnorm2")


def speedup(wl: WorkloadConfig, accel: AccelSpec, cores: int = 1) -> float:
    r = simulate_layer(wl, accel, "rwma", cores)["total"].cycles
    bwma = simulate_layer(wl, accel, "bwma", cores)["total"].cycles
    return r / bwma


def conversion_overhead_fraction(wl: WorkloadConfig, accel: AccelSpec,
                                 n_layers: int = 12) -> float:
    """Paper §3.2: RWMA<->BWMA conversion cost vs whole-model run-time."""
    # conversion = read + write of the SxD input and output matrices once
    conv_lines = 2 * 2 * (wl.seq * wl.d_model * accel.esize) // LINE
    conv_cycles = conv_lines * CacheConfig().lat_dram_burst
    layer = simulate_layer(wl, accel, "bwma")["total"].cycles
    return conv_cycles / (layer * n_layers + conv_cycles)

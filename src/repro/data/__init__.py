from repro.data.pipeline import SyntheticLMData, TokenFileData, make_batch_sharded

__all__ = ["SyntheticLMData", "TokenFileData", "make_batch_sharded"]

"""Data pipeline: deterministic synthetic LM stream + memmap token files.

Batches are materialized *per shard* with ``jax.make_array_from_callback`` —
each host/device only generates its own slice (the multi-host pattern; on
1000+ nodes no host ever holds the global batch).  The synthetic stream is a
seeded PRNG so runs are reproducible and restart-consistent: batch contents
depend only on (seed, step), never on world size or host count (elastic
restarts resume bit-identically).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig


def make_batch_sharded(global_shape, dtype, sharding: NamedSharding, fill_fn):
    """Build a global array shard-by-shard.  fill_fn(index_tuple) -> np array."""
    return jax.make_array_from_callback(
        global_shape, sharding, lambda idx: np.asarray(fill_fn(idx), dtype=dtype)
    )


@dataclasses.dataclass
class SyntheticLMData:
    """Deterministic synthetic next-token stream (zipf-ish token marginals)."""

    cfg: ModelConfig
    global_batch: int
    seq_len: int
    seed: int = 0

    def _tokens(self, step: int, lo: int, hi: int) -> np.ndarray:
        """Rows [lo, hi) of the global batch at ``step`` — pure function."""
        rows = []
        for r in range(lo, hi):
            rng = np.random.default_rng(
                (self.seed * 1_000_003 + step) * 131_071 + r
            )
            # zipf-like marginals bounded to vocab
            z = rng.zipf(1.3, size=self.seq_len + 1)
            rows.append(np.minimum(z - 1, self.cfg.vocab_size - 1))
        return np.stack(rows).astype(np.int32)

    def batch(self, step: int, shardings: Optional[Dict] = None) -> Dict:
        """One {tokens, labels} batch (+ frontend stubs), optionally sharded."""
        B, S = self.global_batch, self.seq_len

        def tok_fill(index):
            rsl = index[0]
            lo = rsl.start or 0
            hi = rsl.stop if rsl.stop is not None else B
            full = self._tokens(step, lo, hi)
            ssl = index[1]
            return full[:, ssl]

        if shardings is not None:
            tokens = make_batch_sharded((B, S), np.int32, shardings["tokens"], tok_fill)
            labels = make_batch_sharded(
                (B, S), np.int32, shardings["labels"],
                lambda idx: np.roll(tok_fill(idx), -1, axis=1),
            )
        else:
            t = self._tokens(step, 0, B)
            tokens, labels = jnp.asarray(t), jnp.asarray(np.roll(t, -1, 1))
        batch = {"tokens": tokens, "labels": labels}
        if self.cfg.frontend == "vision":
            nv = min(self.cfg.n_frontend_tokens, S)
            rng = np.random.default_rng(self.seed + 7 + step)
            batch["vis_embeds"] = jnp.asarray(
                rng.standard_normal((B, nv, self.cfg.d_model)).astype(np.float32),
                dtype=self.cfg.dtype,
            )
            batch["positions3"] = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, S)
            )
        if self.cfg.frontend == "audio":
            rng = np.random.default_rng(self.seed + 11 + step)
            batch["audio_embeds"] = jnp.asarray(
                rng.standard_normal(
                    (B, self.cfg.encoder_seq, self.cfg.d_model)
                ).astype(np.float32),
                dtype=self.cfg.dtype,
            )
        return batch

    def __iter__(self) -> Iterator[Dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


@dataclasses.dataclass
class TokenFileData:
    """Memory-mapped pre-tokenized corpus (one flat int32 token stream)."""

    path: str
    global_batch: int
    seq_len: int
    seed: int = 0

    def __post_init__(self):
        self._mm = np.memmap(self.path, dtype=np.int32, mode="r")
        self._n = len(self._mm) - self.seq_len - 1
        if self._n <= 0:
            raise ValueError(f"{self.path} too small for seq_len {self.seq_len}")

    def batch(self, step: int) -> Dict:
        rng = np.random.default_rng(self.seed + step)
        starts = rng.integers(0, self._n, size=self.global_batch)
        toks = np.stack([self._mm[s : s + self.seq_len] for s in starts])
        labs = np.stack([self._mm[s + 1 : s + self.seq_len + 1] for s in starts])
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labs)}

from repro.distributed.sharding import (
    MeshAxes,
    batch_pspecs,
    cache_pspecs,
    opt_pspecs,
    param_pspecs,
)

__all__ = [
    "MeshAxes",
    "batch_pspecs",
    "cache_pspecs",
    "opt_pspecs",
    "param_pspecs",
]

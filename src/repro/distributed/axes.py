"""Activation sharding constraints, mesh-agnostic model code.

Model code cannot see the mesh at trace time (the ambient abstract mesh is
empty under a plain ``with mesh:`` block), so the launcher installs a
:class:`ShardPolicy` around tracing and the model calls :func:`constrain`
with *logical* dims ("dp" = batch, "tp" = model-parallel).  Outside a policy
(unit tests, single device) it is a no-op.

These constraints are what keep GSPMD from replicating the big activations
(e.g. (B, S, vocab) logits) when a ZeRO-sharded weight's storage layout
conflicts with the activation layout.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import AbstractMesh
from jax.sharding import PartitionSpec as P


def abstract_mesh(
    axis_sizes: Sequence[int], axis_names: Sequence[str]
) -> AbstractMesh:
    """Version-portable :class:`jax.sharding.AbstractMesh` construction.

    The constructor signature has changed across jax releases: older
    versions take ``AbstractMesh(shape_tuple)`` with ``((name, size), ...)``
    pairs, newer ones take ``AbstractMesh(axis_sizes, axis_names)``.  All
    mesh-shape validation (tests, launch dry-runs) should build meshes here
    so a jax bump touches one place.
    """
    sizes: Tuple[int, ...] = tuple(int(s) for s in axis_sizes)
    names: Tuple[str, ...] = tuple(axis_names)
    if len(sizes) != len(names):
        raise ValueError(f"{len(sizes)} axis sizes for {len(names)} names")
    try:
        return AbstractMesh(sizes, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))


@dataclasses.dataclass(frozen=True)
class ShardPolicy:
    dp_axes: tuple
    tp_axis: str
    dp_size: int
    tp_size: int
    ep_axes: tuple = ()   # innermost-data x model (full expert parallelism)
    ep_size: int = 1
    # the concrete Mesh the policy was derived from — needed by trace-time
    # consumers that must name a mesh explicitly (shard_map around the
    # pallas paged-decode kernels).  None under AbstractMesh validation.
    mesh: object = dataclasses.field(default=None, compare=False)


_CURRENT: Optional[ShardPolicy] = None


def current() -> Optional[ShardPolicy]:
    return _CURRENT


@contextlib.contextmanager
def policy(mesh):
    """Install the shard policy derived from ``mesh`` for the trace scope."""
    global _CURRENT
    names = mesh.axis_names
    dp = tuple(n for n in names if n != "model")
    tp = "model" if "model" in names else ""
    prev = _CURRENT
    ep = (dp[-1], tp) if (dp and tp) else ()
    _CURRENT = ShardPolicy(
        dp_axes=dp,
        tp_axis=tp,
        dp_size=int(np.prod([mesh.shape[a] for a in dp])) if dp else 1,
        tp_size=mesh.shape[tp] if tp else 1,
        ep_axes=ep,
        ep_size=(mesh.shape[dp[-1]] * mesh.shape[tp]) if ep else 1,
        mesh=mesh if isinstance(mesh, jax.sharding.Mesh) else None,
    )
    try:
        yield _CURRENT
    finally:
        _CURRENT = prev


def traced_under(mesh, fn):
    """Wrap ``fn`` so its BODY runs under ``with mesh, policy(mesh)``.

    ``jax.jit`` traces lazily at the first call, so a mesh/policy context
    installed around jit *construction* is gone by trace time and every
    :func:`constrain` inside the model silently no-ops.  Wrapping the
    function body instead puts the context where tracing actually happens —
    the engine's sharded step closures are all built through here.
    """
    @functools.wraps(fn)
    def run(*args, **kwargs):
        with mesh, policy(mesh):
            return fn(*args, **kwargs)

    return run


def constrain(x, dims: Sequence[Optional[str]]):
    """Apply with_sharding_constraint using logical dims.

    dims entries: "dp" (batch axes), "tp" (model axis), "dp+tp" (flattened,
    for pure sequence parallelism), or None.  Any entry whose size doesn't
    divide is silently dropped (the rules must hold for every arch).
    """
    pol = _CURRENT
    if pol is None or (pol.dp_size == 1 and pol.tp_size == 1):
        return x
    spec = []
    for dim, size in zip(dims, x.shape):
        if dim == "dp" and pol.dp_axes and size % pol.dp_size == 0:
            spec.append(pol.dp_axes if len(pol.dp_axes) > 1 else pol.dp_axes[0])
        elif dim == "tp" and pol.tp_axis and size >= pol.tp_size:
            # GSPMD pads uneven dims; vocab (e.g. 51865) still shards.
            spec.append(pol.tp_axis)
        elif dim == "ep":
            # expert dim: full (data x model) EP if it divides, else TP
            # (uneven is tolerated: dropping the constraint entirely was
            # measured strictly worse — GSPMD replicates the dispatch buffer:
            # granite prefill temp 24 -> 120 GiB without it).
            if pol.ep_axes and size % pol.ep_size == 0:
                spec.append(pol.ep_axes)
            elif pol.tp_axis and size >= pol.tp_size:
                spec.append(pol.tp_axis)
            else:
                spec.append(None)
        elif dim == "dp+tp" and pol.tp_axis and pol.dp_axes and (
            size % (pol.dp_size * pol.tp_size) == 0
        ):
            spec.append(pol.dp_axes + (pol.tp_axis,))
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))

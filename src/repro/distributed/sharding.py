"""Sharding rules: parameter / optimizer / batch / cache PartitionSpecs.

The production mesh is ``(pod, data, model)`` (multi-pod) or ``(data, model)``
(single pod).  Axis roles:

* DP/FSDP — batch and ZeRO-sharded parameter/optimizer storage over
  ``("pod", "data")``;
* TP      — attention-head / FFN-hidden / expert / vocab dims over ``"model"``
  (Megatron column/row pattern);
* EP      — MoE expert dim over ``"model"`` when E divides; otherwise the
  per-expert hidden is TP-sharded instead (granite's 40 experts vs 16-way
  axis — documented trade-off);
* SP      — decode caches shard the *sequence* dim so 32k/500k contexts fit
  (flash-style distributed softmax is inserted by GSPMD).

Rules are name/shape driven: each parameter leaf's path decides its base TP
spec, then ZeRO extension shards the largest remaining dim over the data
axes when divisible.  Anything non-divisible falls back gracefully —
the rules must produce *valid* specs for every architecture in the pool.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    dp: Tuple[str, ...]  # data-parallel axes (("pod","data") or ("data",))
    tp: str = "model"

    @staticmethod
    def from_mesh(mesh: Mesh) -> "MeshAxes":
        names = mesh.axis_names
        return MeshAxes(dp=tuple(n for n in names if n != "model"), tp="model")


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def _dp_size(mesh: Mesh, ax: MeshAxes) -> int:
    return int(np.prod([_axis_size(mesh, a) for a in ax.dp]))


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


# --------------------------------------------------------------------------
# Base TP rules
# --------------------------------------------------------------------------

_COL_PARALLEL = (  # shard output (last) dim over tp
    "wq", "wk", "wv", "w_gate", "w_up", "wq_b", "wkv_b", "wq_a", "lm_head",
    "bq", "bk", "bv", "b_up",
)
_ROW_PARALLEL = ("wo", "w_down")  # shard input (second-to-last) dim over tp


def _base_tp_spec(name: str, shape: Tuple[int, ...], tp: str, tp_size: int,
                  stacked: bool, cfg: ModelConfig) -> P:
    """TP placement by parameter name.  ``stacked`` = leading L axis."""
    off = 1 if stacked else 0
    none = [None] * len(shape)

    def spec(idx, axis):
        s = list(none)
        s[idx] = axis
        return P(*s)

    if name == "embed":
        if shape[0] % tp_size == 0:
            return spec(0, tp)  # vocab-sharded
        if shape[1] % tp_size == 0:
            return spec(1, tp)  # fallback: d_model-sharded
        return P(*none)
    if name in ("w_gate", "w_up", "w_down") and len(shape) == 3 + off:
        # MoE expert weights (L, E, d, f) / (L, E, f, d)
        E = shape[off]
        if E % tp_size == 0:
            return spec(off, tp)  # EP
        # shard the per-expert hidden dim instead
        h_idx = len(shape) - 1 if name != "w_down" else len(shape) - 2
        if shape[h_idx] % tp_size == 0:
            return spec(h_idx, tp)
        return P(*none)
    if name in _COL_PARALLEL:
        if shape[-1] % tp_size == 0:
            return spec(len(shape) - 1, tp)
        return P(*none)
    if name in _ROW_PARALLEL:
        if shape[-2] % tp_size == 0:
            return spec(len(shape) - 2, tp)
        return P(*none)
    return P(*none)  # norms, routers, ssm (replicated base), biases


def _zero_extend(spec: P, shape: Tuple[int, ...], dp: Tuple[str, ...],
                 dp_size: int) -> P:
    """ZeRO/FSDP: shard the largest still-unsharded dim over the data axes."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if entries[i] is None and shape[i] % dp_size == 0 and shape[i] >= dp_size:
            entries[i] = dp if len(dp) > 1 else dp[0]
            return P(*entries)
    return P(*entries)


# Models below this many params are replicated in training (pure DP):
# FSDP-gathering a 130M model costs more wire traffic than it saves HBM.
REPLICATE_BELOW = 5e8


def ep_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Expanded expert-parallel axes: innermost data axis × model axis.

    DeepSeek-V3's 256 experts shard exactly 256 ways on both production
    meshes (data 16 × model 16), so expert weights are never FSDP-gathered —
    they stay resident and only the routed tokens cross the network
    (all-to-all), which is the whole point of expert parallelism.
    """
    ax = MeshAxes.from_mesh(mesh)
    return (ax.dp[-1], ax.tp)


def param_pspecs(cfg: ModelConfig, mesh: Mesh, params_shape,
                 *, zero: bool = True, mode: str = "train") -> Any:
    """PartitionSpec pytree matching ``params_shape`` (eval_shape output).

    mode="train": Megatron TP + ZeRO/FSDP storage extension over data axes.
    mode="serve": 2-D tensor parallelism over ALL axes — weights stay
    resident (no per-step FSDP gathers; decode is latency-bound and
    re-gathering 1/tp of the model every token dwarfs everything else).
    """
    ax = MeshAxes.from_mesh(mesh)
    tp_size = _axis_size(mesh, ax.tp)
    dp_size = _dp_size(mesh, ax)
    if mode == "serve":
        serve_axes = ax.dp + (ax.tp,)
        serve_size = dp_size * tp_size
    replicate = (mode == "train" and zero
                 and cfg.param_count() < REPLICATE_BELOW)

    def rule(path, leaf):
        name = None
        for k in reversed(path):
            if hasattr(k, "key"):
                name = str(k.key)
                break
        pstr = _path_str(path)
        stacked = any(seg.startswith("seg") or seg in ("encoder", "cross")
                      for seg in pstr.split("/"))
        if replicate:
            return P(*([None] * leaf.ndim))
        if mode == "serve":
            spec = _base_tp_spec(name, leaf.shape, serve_axes, serve_size,
                                 stacked, cfg)
            if any(e is not None for e in spec):
                return spec
            # 1-D over all axes didn't divide (e.g. qwen2-vl d_ff=29568 vs
            # 256): shard the matrix 2-D instead — rows over the data axes,
            # cols over the model axis — so weights stay fully resident.
            if leaf.ndim >= 2:
                r, c = leaf.shape[-2], leaf.shape[-1]
                dp_comb = ax.dp if len(ax.dp) > 1 else ax.dp[0]
                entries = [None] * leaf.ndim
                if r % dp_size == 0 and c % tp_size == 0:
                    entries[-2], entries[-1] = dp_comb, ax.tp
                    return P(*entries)
                if r % tp_size == 0 and c % dp_size == 0:
                    entries[-2], entries[-1] = ax.tp, dp_comb
                    return P(*entries)
            # last resort: TP + ZeRO storage (re-gathers per step, but never
            # 100+ GiB of replicated weights)
            spec = _base_tp_spec(name, leaf.shape, ax.tp, tp_size, stacked, cfg)
            return _zero_extend(spec, leaf.shape, ax.dp, dp_size)
        # NOTE on full (data x model) expert parallelism: tried and REFUTED
        # on this partitioner — EP-resident expert weights made GSPMD emit
        # f32 expert-grad all-reduces across the pod axis (36.5 GiB/iter vs
        # 3.5 GiB baseline) plus involuntary full rematerializations.  See
        # EXPERIMENTS.md §Perf iteration 2.  Experts stay E-over-tp with
        # ZeRO storage extension (the all-to-all still happens via the
        # token-side constraint in ffn.moe_forward).
        spec = _base_tp_spec(name, leaf.shape, ax.tp, tp_size, stacked, cfg)
        if zero:
            spec = _zero_extend(spec, leaf.shape, ax.dp, dp_size)
        return spec

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def opt_pspecs(cfg: ModelConfig, mesh: Mesh, opt_shape, param_specs) -> Any:
    """Optimizer moments mirror the (ZeRO-extended) parameter specs."""
    return {
        "m": param_specs,
        "v": param_specs,
        "step": P(),
    }


# --------------------------------------------------------------------------
# Batch / cache rules
# --------------------------------------------------------------------------

def batch_pspecs(cfg: ModelConfig, mesh: Mesh, batch_shape: Dict) -> Dict:
    ax = MeshAxes.from_mesh(mesh)
    dp = ax.dp if len(ax.dp) > 1 else ax.dp[0]
    dp_size = _dp_size(mesh, ax)

    def rule(path, leaf):
        name = _path_str(path).split("/")[-1]
        if name == "positions3":  # (3, B, S)
            b = leaf.shape[1]
            return P(None, dp, None) if b % dp_size == 0 else P()
        if leaf.ndim == 0:
            return P()
        b = leaf.shape[0]
        rest = [None] * (leaf.ndim - 1)
        if b % dp_size == 0:
            return P(dp, *rest)
        # small batches: shard over the largest dp sub-axis that divides
        for a in sorted(ax.dp, key=lambda a: -_axis_size(mesh, a)):
            if b % _axis_size(mesh, a) == 0 and b >= _axis_size(mesh, a):
                return P(a, *rest)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(rule, batch_shape)


def cache_pspecs(cfg: ModelConfig, mesh: Mesh, cache_shape) -> Any:
    """Decode caches: batch over DP when divisible, sequence over TP (SP);
    tiny leaves (SSM states, ring buffers) fall back sensibly."""
    ax = MeshAxes.from_mesh(mesh)
    tp_size = _axis_size(mesh, ax.tp)
    dp_size = _dp_size(mesh, ax)
    dp = ax.dp if len(ax.dp) > 1 else ax.dp[0]

    def rule(path, leaf):
        name = _path_str(path).split("/")[-1]
        shape = leaf.shape
        entries = [None] * len(shape)
        if name in ("k", "v", "ckv", "krope", "pos"):
            # (L, B, S, ...) — stacked per segment
            b_idx, s_idx = 1, 2
            if shape[b_idx] % dp_size == 0:
                entries[b_idx] = dp
                if shape[s_idx] % tp_size == 0:
                    entries[s_idx] = ax.tp
            else:
                # batch too small (long_500k): full sequence parallelism
                flat = ax.dp + (ax.tp,)
                total = dp_size * tp_size
                if shape[s_idx] % total == 0:
                    entries[s_idx] = flat
                elif shape[s_idx] % tp_size == 0:
                    entries[s_idx] = ax.tp
            return P(*entries)
        if name in ("state", "conv"):  # SSM: (L, B, ...)
            if shape[1] % dp_size == 0:
                entries[1] = dp
            return P(*entries)
        return P(*entries)

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def paged_cache_pspecs(cfg: ModelConfig, mesh: Mesh, cache_shape=None) -> Any:
    """PartitionSpec pytree for the engine's **L-stacked paged cache pools**
    (the ``init_paged_cache`` tree: ``seg{i} -> adapter.key -> pool leaf``).

    Placement is each family's cache adapter's business
    (:meth:`repro.models.adapters.CacheAdapter.pool_pspecs`): dense/GQA and
    ring/cross pools shard their kv-head axis over the model axis when it
    divides; MLA latent pools replicate (no head axis); SSM state rows
    replicate.  Page tables and free lists are host-side and never enter
    this tree.  ``cache_shape`` (any pytree with the pool leaf names — real
    arrays, ``eval_shape`` output...) is optional: without it the leaf
    names are recovered from an ``eval_shape`` of each adapter's pool.
    """
    from repro.models import adapters as A

    ax = MeshAxes.from_mesh(mesh)
    tp_size = _axis_size(mesh, ax.tp)

    def leaf_names(si: int, ad) -> Tuple[str, ...]:
        if cache_shape is not None:
            return tuple(cache_shape[f"seg{si}"][ad.key])
        geom = A.CacheGeometry(max_seqs=1, num_pages=2,
                               page_size=cfg.block, max_len=cfg.block)
        return tuple(jax.eval_shape(lambda: ad.init_pool(cfg, geom)))

    out: Dict[str, Any] = {}
    for si, (kind, _n) in enumerate(A.layer_segments(cfg)):
        seg: Dict[str, Any] = {}
        for ad in A.adapters_for(cfg, kind):
            specs = ad.pool_pspecs(cfg, tp_axis=ax.tp, tp_size=tp_size)
            seg[ad.key] = {
                name: specs.get(name, P()) for name in leaf_names(si, ad)
            }
        out[f"seg{si}"] = seg
    return out


def validate_paged_sharding(cfg: ModelConfig, mesh: Mesh) -> None:
    """Reject (config, mesh) pairs whose paged K/V head axis cannot shard.

    Called at :class:`~repro.serve.engine.Engine` construction so a
    non-dividing head count fails fast with an actionable message instead
    of silently replicating the pools (or failing inside jit).  Families
    without a head-axis pool (MLA latent, SSM rows) pass — their pools
    replicate by design.
    """
    from repro.models import adapters as A

    ax = MeshAxes.from_mesh(mesh)
    tp_size = _axis_size(mesh, ax.tp)
    if tp_size <= 1:
        return
    uses_paged_heads = any(
        isinstance(ad, A.PagedAttnAdapter) for ad in A.all_adapters(cfg)
    )
    if uses_paged_heads and cfg.n_kv_heads % tp_size:
        divisors = [m for m in range(1, cfg.n_kv_heads + 1)
                    if cfg.n_kv_heads % m == 0]
        raise ValueError(
            f"{cfg.name}: n_kv_heads={cfg.n_kv_heads} is not divisible by "
            f"the mesh's model-axis size {tp_size}, so the paged K/V pools "
            f"cannot head-shard (they would silently replicate on every "
            f"device).  Pick a mesh whose model axis divides n_kv_heads "
            f"(valid TP sizes: {divisors}) or serve single-device."
        )


def serve_shardings(cfg: ModelConfig, mesh: Mesh, params, cache_shape):
    """The serving engine's NamedSharding bundle for one (config, mesh).

    Returns ``(param_shardings, pool_shardings, replicated)`` — resident
    2-D TP for the weights (``param_pspecs(mode="serve")``), the adapter
    registry's pool placement for the L-stacked cache, and the replicated
    sharding used for every small host-fed step input (tokens, positions,
    page tables, scalars).
    """
    params_shape = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params
    )
    p_specs = param_pspecs(cfg, mesh, params_shape, mode="serve")
    c_specs = paged_cache_pspecs(cfg, mesh, cache_shape)
    return (
        named(mesh, p_specs),
        named(mesh, c_specs),
        NamedSharding(mesh, P()),
    )


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )

"""Leading-dim (batch / head) support for the Pallas BWMA kernels.

The kernels themselves are written for a single blocked matrix — a 4-D
``(gm, gn, bm, bn)`` array — because one ``pallas_call`` grid covers one
logical GEMM/softmax/etc.  A transformer encoder, however, wants to run the
same kernel across every head (and every batch element) at once.  Rather
than teaching each kernel's grid about extra axes, we lift them with
``jax.vmap``: Pallas registers a batching rule for ``pallas_call``, so the
vmapped kernel becomes a single call with one extra leading grid dimension —
still one contiguous block DMA per step, which is the property the paper's
arrangement exists to provide.

:func:`batched_call` is the shared adapter: each operand declares its core
rank (4 for blocked matrices, 2 for blocked vectors); any leading axes beyond
that are broadcast together, flattened to one vmap axis, and restored on the
output.  Operands with no leading axes (weights shared across heads) are
passed through unbatched (``in_axes=None``), so they are not materialized
per head.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp


def batched_call(
    fn: Callable[..., jnp.ndarray],
    args: Sequence[jnp.ndarray],
    core_ndims: Sequence[int],
) -> jnp.ndarray:
    """Apply ``fn`` (which expects core-rank operands) over leading dims.

    ``args[i]`` may carry any number of leading axes beyond ``core_ndims[i]``;
    leading shapes broadcast against each other (numpy rules).  Each
    non-trivial lead axis becomes one ``vmap`` level, with ``in_axes=None``
    for operands that lack it — an operand is never physically replicated
    along an axis it broadcasts over (batched activations do not copy the
    shared weights).  With no leading axes anywhere this is ``fn(*args)``.
    """
    if len(args) != len(core_ndims):
        raise ValueError(f"{len(args)} args vs {len(core_ndims)} core ranks")
    leads = [a.shape[: a.ndim - c] for a, c in zip(args, core_ndims)]
    lead = jnp.broadcast_shapes(*leads)
    if lead == ():
        return fn(*args)
    n = len(lead)
    keep = [j for j in range(n) if lead[j] != 1]
    prepped = []
    present = []  # which kept lead axes each arg actually carries
    for a, c, ld in zip(args, core_ndims, leads):
        padded = (1,) * (n - len(ld)) + ld
        mine = [j for j in keep if padded[j] != 1]
        core = a.shape[a.ndim - c:]
        # drop size-1 lead axes: they are pure broadcast (handled by
        # in_axes=None below), and removing them is a free reshape.
        prepped.append(a.reshape(tuple(lead[j] for j in mine) + core))
        present.append(set(mine))
    f = fn
    for j in reversed(keep):
        f = jax.vmap(f, in_axes=tuple(0 if j in p else None for p in present))
    out = f(*prepped)
    return out.reshape(lead + out.shape[len(keep):])

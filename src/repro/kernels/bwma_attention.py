"""Fused blocked attention: scores -> softmax -> @V in one Pallas kernel.

The paper's §3.2 argument is that every encoder intermediate can stay in the
accelerator-block arrangement.  The attention inner loop is the strongest
case: the ``(S, S)`` score matrix never needs to exist in HBM at all.  One
grid step here owns one *query block-row* and, entirely in VMEM:

1. computes its row of blocked scores against all of K (``q @ k^T``, block
   by block — each K fetch is one contiguous BWMA burst),
2. applies the scaled, padding-masked softmax over that row (the same
   index arithmetic as :mod:`repro.kernels.bwma_softmax`),
3. multiplies the probabilities into V and writes one blocked output row.

Inputs/outputs are all ``(gs, gd, b, b)`` blocked matrices with logical
shape ``(seq, d_head)`` — i.e. the exact values the blocked QKV GEMMs
produce, so the whole attention block is three kernel launches (QKV) plus
this one, with no rearrangement between them.

Padding semantics match the reference path (:func:`repro.core.blockwise`
operators): padded *key* positions get probability exactly 0; padded
*d_head* columns stay exactly 0; padded query rows produce garbage that is
cropped at unblock time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.blockwise import Blocked
from repro.kernels.batching import batched_call


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, s_logical: int, scale: float):
    q = q_ref[0].astype(jnp.float32)  # (gd, bm, bd) — one query block-row
    k = k_ref[...].astype(jnp.float32)  # (gs, gd, bs, bd) — all of K
    v = v_ref[...].astype(jnp.float32)  # (gs, gd, bs, bd) — all of V
    gs, _, bs, _ = k.shape
    bm = q.shape[1]
    # blocked score row: scores[j][a, c] = sum_d q[d, a, :] . k[j, d, c, :]
    s = jnp.einsum("dab,jdcb->jac", q, k) * scale  # (gs, bm, bs)
    key_idx = (
        jax.lax.broadcasted_iota(jnp.int32, (gs, bm, bs), 0) * bs
        + jax.lax.broadcasted_iota(jnp.int32, (gs, bm, bs), 2)
    )
    mask = key_idx < s_logical
    neg = jnp.finfo(jnp.float32).min
    sm = jnp.where(mask, s, neg)
    m = jnp.max(sm, axis=(0, 2), keepdims=True)
    e = jnp.where(mask, jnp.exp(sm - m), 0.0)
    z = jnp.sum(e, axis=(0, 2), keepdims=True)
    p = e / jnp.maximum(z, 1e-30)  # (gs, bm, bs)
    o = jnp.einsum("jac,jdcb->dab", p, v)  # (gd, bm, bd)
    o_ref[0] = o.astype(o_ref.dtype)


def _attention_4d(q, k, v, *, s_logical, scale, interpret):
    gs, gd, bm, bd = q.shape
    if k.shape != q.shape or v.shape != q.shape:
        raise ValueError(f"q/k/v blocked shapes differ: {q.shape} {k.shape} {v.shape}")
    kernel = functools.partial(_attention_kernel, s_logical=s_logical, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(gs,),
        in_specs=[
            pl.BlockSpec((1, gd, bm, bd), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((gs, gd, bm, bd), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((gs, gd, bm, bd), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, gd, bm, bd), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)


def bwma_attention(
    q,
    k,
    v,
    *,
    scale: float,
    s_logical: int | None = None,
    interpret: bool = False,
):
    """softmax(q @ k^T * scale) @ v, entirely in BWMA order.

    q/k/v: ``(..., gs, gd, b, b)`` blocked matrices of logical shape
    ``(seq, d_head)`` — raw arrays (``s_logical`` required) or
    :class:`Blocked` wrappers.  Leading dims (batch, heads) broadcast.
    """
    wrapped = isinstance(q, Blocked)
    if wrapped != isinstance(k, Blocked) or wrapped != isinstance(v, Blocked):
        raise TypeError(
            "pass q/k/v all as Blocked or all as raw blocked arrays"
        )
    qa = q.data if wrapped else q
    ka = k.data if wrapped else k
    va = v.data if wrapped else v
    if s_logical is None:
        if not wrapped:
            raise ValueError("s_logical is required for raw blocked arrays")
        s_logical = q.shape[0]
    fn = functools.partial(
        _attention_4d, s_logical=s_logical, scale=scale, interpret=interpret
    )
    out = batched_call(fn, (qa, ka, va), (4, 4, 4))
    if wrapped:
        return Blocked(out, q.shape, q.layout)
    return out

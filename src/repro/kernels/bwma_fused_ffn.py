"""Blocked GEMM + bias + GELU fusion (paper §3.2 Activation).

The paper notes the activation is element-wise and therefore fused into the
feed-forward GEMM 'immediately prior to saving the computed values back into
the memory', costing zero extra memory traffic.  This kernel realizes that on
TPU: at the final reduction step (k == gk-1), the epilogue applies bias+GELU
on the accumulator while it is still resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.blockwise import Blocked
from repro.kernels.batching import batched_call


def _ffn_kernel(a_ref, b_ref, bias_ref, o_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[0, 0] += jnp.dot(
        a_ref[0, 0], b_ref[0, 0], preferred_element_type=o_ref.dtype
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        o_ref[0, 0] = jax.nn.gelu(o_ref[0, 0] + bias_ref[...].astype(o_ref.dtype))


def _ffn_4d(a_blocked, w_blocked, bias_blocked, *, acc_dtype, interpret):
    gm, gk, bm, bk = a_blocked.shape
    _, gn, _, bn = w_blocked.shape
    kernel = functools.partial(_ffn_kernel, n_k=gk)
    return pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((1, 1, bm, bk), lambda i, j, k: (i, k, 0, 0)),
            pl.BlockSpec((1, 1, bk, bn), lambda i, j, k: (k, j, 0, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bm, bn), lambda i, j, k: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((gm, gn, bm, bn), acc_dtype),
        interpret=interpret,
    )(a_blocked, w_blocked, bias_blocked)


def bwma_fused_ffn(
    a_blocked,
    w_blocked,
    bias_blocked: jnp.ndarray,
    *,
    acc_dtype=jnp.float32,
    interpret: bool = False,
):
    """gelu((..., gm,gk,bm,bk) @ (gk,gn,bk,bn) + bias(gn,bn)) -> (..., gm,gn,bm,bn).

    Accepts raw blocked arrays or :class:`Blocked` wrappers for the matrix
    operands; the bias stays a raw blocked vector.  Leading dims on the
    activation broadcast; the weight/bias are shared.
    """
    wrapped = isinstance(a_blocked, Blocked)
    if wrapped != isinstance(w_blocked, Blocked):
        raise TypeError(
            "pass both matrix operands as Blocked or both as raw blocked arrays"
        )
    a = a_blocked.data if wrapped else a_blocked
    w = w_blocked.data if wrapped else w_blocked
    fn = functools.partial(_ffn_4d, acc_dtype=acc_dtype, interpret=interpret)
    out = batched_call(fn, (a, w, bias_blocked), (4, 4, 2))
    if wrapped:
        out = out.astype(a_blocked.dtype)
        return Blocked(out, (a_blocked.shape[0], w_blocked.shape[1]), a_blocked.layout)
    return out

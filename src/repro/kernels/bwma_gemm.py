"""BWMA blocked GEMM — the paper's technique as a Pallas TPU kernel.

Inputs are stored block-wise (4-D, trailing dims = one accelerator block), so
the ``BlockSpec`` for every grid step selects ``(1, 1, bm, bk)`` — a single
**contiguous** HBM region.  Pallas double-buffers the next grid step's DMA
while the MXU computes the current block: contiguity makes that DMA one burst
descriptor, which is exactly the paper's prefetch-alignment argument mapped to
the TPU memory system.

Contrast with :mod:`repro.kernels.rwma_gemm`, which implements the identical
tiling over *row-major* operands: its per-step DMA gathers ``bm`` separate
row segments (strided descriptor), the TPU analogue of the paper's RWMA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.blockwise import Blocked
from repro.kernels.batching import batched_call


def _gemm_kernel(a_ref, b_ref, o_ref, *, n_k: int):
    """One (i, j, k) grid step: o[i,j] += a[i,k] @ b[k,j] on the MXU."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[0, 0]  # (bm, bk) — fetched as one contiguous block
    b = b_ref[0, 0]  # (bk, bn)
    o_ref[0, 0] += jnp.dot(a, b, preferred_element_type=o_ref.dtype)


def _gemm_4d(a_blocked, b_blocked, *, acc_dtype, interpret):
    gm, gk, bm, bk = a_blocked.shape
    gk2, gn, bk2, bn = b_blocked.shape
    if (gk, bk) != (gk2, bk2):
        raise ValueError(f"inner blocks mismatch: {a_blocked.shape} @ {b_blocked.shape}")
    kernel = functools.partial(_gemm_kernel, n_k=gk)
    out = pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=[
            # contiguous: one block per step (BWMA — paper Fig. 4d)
            pl.BlockSpec((1, 1, bm, bk), lambda i, j, k: (i, k, 0, 0)),
            pl.BlockSpec((1, 1, bk, bn), lambda i, j, k: (k, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bm, bn), lambda i, j, k: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((gm, gn, bm, bn), acc_dtype),
        interpret=interpret,
    )(a_blocked, b_blocked)
    return out


def bwma_gemm(
    a_blocked,
    b_blocked,
    *,
    acc_dtype=jnp.float32,
    interpret: bool = False,
):
    """(..., gm, gk, bm, bk) @ (..., gk, gn, bk, bn) -> (..., gm, gn, bm, bn).

    Accepts raw blocked arrays or :class:`Blocked` wrappers (returned type
    follows the inputs).  Leading dims (batch, heads) broadcast; weights
    without leading dims are shared, not replicated.
    """
    wrapped = isinstance(a_blocked, Blocked)
    if wrapped != isinstance(b_blocked, Blocked):
        raise TypeError(
            "pass both operands as Blocked or both as raw blocked arrays"
        )
    a, b = a_blocked, b_blocked
    if wrapped:
        if a.shape[1] != b.shape[0]:
            raise ValueError(f"inner dims mismatch: {a.shape} @ {b.shape}")
        a, b = a_blocked.data, b_blocked.data
    fn = functools.partial(_gemm_4d, acc_dtype=acc_dtype, interpret=interpret)
    out = batched_call(fn, (a, b), (4, 4))
    if wrapped:
        out = out.astype(a_blocked.dtype)
        return Blocked(out, (a_blocked.shape[0], b_blocked.shape[1]), a_blocked.layout)
    return out

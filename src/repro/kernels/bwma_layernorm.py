"""Blocked LayerNorm — the paper's §3.2 Normalization on the BWMA layout.

gamma/beta are stored block-wise as (gn, bn): the whole residual+norm path
never leaves block order, so no rearrangement is needed between layers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.blockwise import Blocked
from repro.kernels.batching import batched_call


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, n_logical: int, bn: int, eps: float):
    x = x_ref[0].astype(jnp.float32)  # (gn, bm, bn)
    gn, bm, _ = x.shape
    col = (
        jax.lax.broadcasted_iota(jnp.int32, (gn, bm, bn), 0) * bn
        + jax.lax.broadcasted_iota(jnp.int32, (gn, bm, bn), 2)
    )
    mask = col < n_logical
    xz = jnp.where(mask, x, 0.0)
    mean = jnp.sum(xz, axis=(0, 2), keepdims=True) / n_logical
    var = jnp.sum(jnp.where(mask, (x - mean) ** 2, 0.0), axis=(0, 2), keepdims=True)
    var = var / n_logical
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    y = y * g_ref[...][:, None, :] + b_ref[...][:, None, :]
    o_ref[0] = jnp.where(mask, y, 0.0).astype(o_ref.dtype)


def _ln_4d(x_blocked, gamma_blocked, beta_blocked, *, n_logical, eps, interpret):
    gm, gn, bm, bn = x_blocked.shape
    kernel = functools.partial(_ln_kernel, n_logical=n_logical, bn=bn, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(gm,),
        in_specs=[
            pl.BlockSpec((1, gn, bm, bn), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((gn, bn), lambda i: (0, 0)),
            pl.BlockSpec((gn, bn), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, gn, bm, bn), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(x_blocked.shape, x_blocked.dtype),
        interpret=interpret,
    )(x_blocked, gamma_blocked, beta_blocked)


def bwma_layernorm(
    x_blocked,
    gamma_blocked: jnp.ndarray,
    beta_blocked: jnp.ndarray,
    n_logical: int | None = None,
    *,
    eps: float = 1e-5,
    interpret: bool = False,
):
    """Row LayerNorm on a (..., gm, gn, bm, bn) blocked matrix.

    gamma/beta are blocked vectors ``(gn, bn)`` shared across all leading
    dims.  Accepts a raw blocked array (``n_logical`` required) or a
    :class:`Blocked` wrapper.
    """
    wrapped = isinstance(x_blocked, Blocked)
    x = x_blocked.data if wrapped else x_blocked
    if n_logical is None:
        if not wrapped:
            raise ValueError("n_logical is required for raw blocked arrays")
        n_logical = x_blocked.shape[1]
    fn = functools.partial(_ln_4d, n_logical=n_logical, eps=eps, interpret=interpret)
    out = batched_call(fn, (x, gamma_blocked, beta_blocked), (4, 2, 2))
    if wrapped:
        return Blocked(out, x_blocked.shape, x_blocked.layout)
    return out

"""Blocked softmax — the paper's §3.2 Softmax, directly on the BWMA layout.

One grid step processes one *block-row*: block shape ``(1, gn, bm, bn)``.
The reduction over a logical row spans axes (gn, bn) of the block; padded
columns (block-quantization of the logical width) are masked with the same
index arithmetic the paper's Fig. 5a describes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.blockwise import Blocked
from repro.kernels.batching import batched_call


def _softmax_kernel(x_ref, o_ref, *, n_logical: int, bn: int):
    x = x_ref[0]  # (gn, bm, bn)
    gn = x.shape[0]
    col = (
        jax.lax.broadcasted_iota(jnp.int32, (gn, x.shape[1], bn), 0) * bn
        + jax.lax.broadcasted_iota(jnp.int32, (gn, x.shape[1], bn), 2)
    )
    mask = col < n_logical
    neg = jnp.finfo(x.dtype).min
    xm = jnp.where(mask, x, neg)
    m = jnp.max(xm, axis=(0, 2), keepdims=True)
    e = jnp.where(mask, jnp.exp(xm - m), 0.0)
    s = jnp.sum(e, axis=(0, 2), keepdims=True)
    o_ref[0] = (e / jnp.maximum(s, 1e-30)).astype(o_ref.dtype)


def _softmax_4d(x_blocked, *, n_logical, interpret):
    gm, gn, bm, bn = x_blocked.shape
    kernel = functools.partial(_softmax_kernel, n_logical=n_logical, bn=bn)
    return pl.pallas_call(
        kernel,
        grid=(gm,),
        in_specs=[pl.BlockSpec((1, gn, bm, bn), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, gn, bm, bn), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(x_blocked.shape, x_blocked.dtype),
        interpret=interpret,
    )(x_blocked)


def bwma_softmax(x_blocked, n_logical: int | None = None, *, interpret: bool = False):
    """Row softmax on a (..., gm, gn, bm, bn) blocked matrix, logical width n.

    Accepts a raw blocked array (``n_logical`` required) or a
    :class:`Blocked` wrapper (``n_logical`` defaults to its logical width).
    """
    wrapped = isinstance(x_blocked, Blocked)
    x = x_blocked.data if wrapped else x_blocked
    if n_logical is None:
        if not wrapped:
            raise ValueError("n_logical is required for raw blocked arrays")
        n_logical = x_blocked.shape[1]
    fn = functools.partial(_softmax_4d, n_logical=n_logical, interpret=interpret)
    out = batched_call(fn, (x,), (4,))
    if wrapped:
        return Blocked(out, x_blocked.shape, x_blocked.layout)
    return out

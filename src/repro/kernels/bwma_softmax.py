"""Blocked softmax — the paper's §3.2 Softmax, directly on the BWMA layout.

One grid step processes one *block-row*: block shape ``(1, gn, bm, bn)``.
The reduction over a logical row spans axes (gn, bn) of the block; padded
columns (block-quantization of the logical width) are masked with the same
index arithmetic the paper's Fig. 5a describes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_kernel(x_ref, o_ref, *, n_logical: int, bn: int):
    x = x_ref[0]  # (gn, bm, bn)
    gn = x.shape[0]
    col = (
        jax.lax.broadcasted_iota(jnp.int32, (gn, x.shape[1], bn), 0) * bn
        + jax.lax.broadcasted_iota(jnp.int32, (gn, x.shape[1], bn), 2)
    )
    mask = col < n_logical
    neg = jnp.finfo(x.dtype).min
    xm = jnp.where(mask, x, neg)
    m = jnp.max(xm, axis=(0, 2), keepdims=True)
    e = jnp.where(mask, jnp.exp(xm - m), 0.0)
    s = jnp.sum(e, axis=(0, 2), keepdims=True)
    o_ref[0] = (e / jnp.maximum(s, 1e-30)).astype(o_ref.dtype)


def bwma_softmax(
    x_blocked: jnp.ndarray, n_logical: int, *, interpret: bool = False
) -> jnp.ndarray:
    """Row softmax on a (gm, gn, bm, bn) blocked matrix with logical width n."""
    gm, gn, bm, bn = x_blocked.shape
    kernel = functools.partial(_softmax_kernel, n_logical=n_logical, bn=bn)
    return pl.pallas_call(
        kernel,
        grid=(gm,),
        in_specs=[pl.BlockSpec((1, gn, bm, bn), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, gn, bm, bn), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(x_blocked.shape, x_blocked.dtype),
        interpret=interpret,
    )(x_blocked)

"""Blocked transpose — the paper's §3.2 Transpose on the BWMA layout.

In BWMA a transpose is two nested small transposes: swap the block-grid
coordinates (done by the output BlockSpec's index map) and transpose each
block's interior (done on-chip in VMEM).  Every block moves HBM->VMEM->HBM
as one contiguous run in both directions — the paper's Fig. 5b locality
argument; the row-major variant gathers strided columns instead.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _transpose_kernel(a_ref, o_ref):
    o_ref[0, 0] = a_ref[0, 0].T


def bwma_transpose(x_blocked: jnp.ndarray, *, interpret: bool = False):
    """(gm, gn, bm, bn) -> (gn, gm, bn, bm): logical transpose, blocked."""
    gm, gn, bm, bn = x_blocked.shape
    return pl.pallas_call(
        _transpose_kernel,
        grid=(gm, gn),
        in_specs=[pl.BlockSpec((1, 1, bm, bn), lambda i, j: (i, j, 0, 0))],
        out_specs=pl.BlockSpec((1, 1, bn, bm), lambda i, j: (j, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((gn, gm, bn, bm), x_blocked.dtype),
        interpret=interpret,
    )(x_blocked)

"""Blocked transpose — the paper's §3.2 Transpose on the BWMA layout.

In BWMA a transpose is two nested small transposes: swap the block-grid
coordinates (done by the output BlockSpec's index map) and transpose each
block's interior (done on-chip in VMEM).  Every block moves HBM->VMEM->HBM
as one contiguous run in both directions — the paper's Fig. 5b locality
argument; the row-major variant gathers strided columns instead.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl

from repro.core.blockwise import Blocked
from repro.core.layout import BlockLayout
from repro.kernels.batching import batched_call


def _transpose_kernel(a_ref, o_ref):
    o_ref[0, 0] = a_ref[0, 0].T


def _transpose_4d(x_blocked, *, interpret):
    gm, gn, bm, bn = x_blocked.shape
    return pl.pallas_call(
        _transpose_kernel,
        grid=(gm, gn),
        in_specs=[pl.BlockSpec((1, 1, bm, bn), lambda i, j: (i, j, 0, 0))],
        out_specs=pl.BlockSpec((1, 1, bn, bm), lambda i, j: (j, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((gn, gm, bn, bm), x_blocked.dtype),
        interpret=interpret,
    )(x_blocked)


def bwma_transpose(x_blocked, *, interpret: bool = False):
    """(..., gm, gn, bm, bn) -> (..., gn, gm, bn, bm): logical transpose."""
    wrapped = isinstance(x_blocked, Blocked)
    x = x_blocked.data if wrapped else x_blocked
    out = batched_call(
        functools.partial(_transpose_4d, interpret=interpret), (x,), (4,)
    )
    if wrapped:
        lo = BlockLayout(x_blocked.layout.bn, x_blocked.layout.bm)
        return Blocked(out, (x_blocked.shape[1], x_blocked.shape[0]), lo)
    return out

"""Public convenience wrappers around the Pallas kernels.

Dispatch policy lives in :class:`repro.core.backend.PallasBackend` (compiled
natively on TPU, ``interpret=True`` elsewhere); these wrappers delegate to
the shared, memoized instance from :func:`resolve_backend` so every caller
hits the same per-shape jit cache.  They also adapt between the logical
(2-D) world and the blocked (BWMA) world using :mod:`repro.core.layout`,
carrying the accelerator block size as the layout quantum (the paper's
'governed by the kernel size').

Dtype contract: the element-wise-shaped ops (softmax/layernorm/attention)
preserve the input dtype, matching the backend convention.  The GEMM-shaped
ops (``blocked_matmul``, ``blocked_ffn``) return the **f32 accumulator**
unless ``out_dtype`` says otherwise — mixed-precision callers depend on
that, so they bypass the backend's input-dtype cast and call the kernels
directly.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.backend import resolve_backend
from repro.core.blockwise import Blocked
from repro.core.layout import BlockLayout, from_blockwise, to_blockwise
from repro.kernels.bwma_fused_ffn import bwma_fused_ffn
from repro.kernels.bwma_gemm import bwma_gemm
from repro.kernels.rwma_gemm import rwma_gemm


def _pallas():
    return resolve_backend("pallas")


def _interpret() -> bool:
    # one source of truth for the dispatch policy: the shared backend
    return _pallas().interpret


@functools.partial(jax.jit, static_argnames=("out_dtype",))
def blocked_matmul(a: Blocked, b: Blocked, out_dtype=None) -> Blocked:
    """BWMA GEMM; returns the f32 accumulator unless ``out_dtype`` is given."""
    out = bwma_gemm(a.data, b.data, interpret=_interpret())
    if out_dtype is not None:
        out = out.astype(out_dtype)
    return Blocked(out, (a.shape[0], b.shape[1]), a.layout)


def blocked_softmax(a: Blocked) -> Blocked:
    return _pallas().softmax(a)


def blocked_layernorm(a: Blocked, gamma_blocked, beta_blocked) -> Blocked:
    return _pallas().layernorm(a, gamma_blocked, beta_blocked)


@functools.partial(jax.jit, static_argnames=("out_dtype",))
def blocked_ffn(a: Blocked, w: Blocked, bias_blocked, out_dtype=None) -> Blocked:
    """Fused GEMM+bias+GELU; f32 accumulator unless ``out_dtype`` is given."""
    out = bwma_fused_ffn(a.data, w.data, bias_blocked, interpret=_interpret())
    if out_dtype is not None:
        out = out.astype(out_dtype)
    return Blocked(out, (a.shape[0], w.shape[1]), a.layout)


def blocked_attention(q: Blocked, k: Blocked, v: Blocked, *, scale: float) -> Blocked:
    """Fused softmax(q @ k^T * scale) @ v without leaving BWMA order."""
    return _pallas().attention(q, k, v, scale=scale)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul_rwma(a: jnp.ndarray, b: jnp.ndarray, bm=128, bk=128, bn=128):
    """Row-major tiled GEMM — the RWMA baseline kernel."""
    return rwma_gemm(a, b, bm=bm, bk=bk, bn=bn, interpret=_interpret())


def matmul_bwma_2d(
    a: jnp.ndarray, b: jnp.ndarray, layout: Optional[BlockLayout] = None
) -> jnp.ndarray:
    """Convenience: 2-D in, 2-D out, blocked internally (conversion at edges
    only — mirrors the paper's whole-model I/O conversion)."""
    layout = layout or BlockLayout(128, 128)
    ab = to_blockwise(a, BlockLayout(layout.bm, layout.bn))
    bb = to_blockwise(b, BlockLayout(layout.bn, layout.bn))
    out = bwma_gemm(ab, bb, interpret=_interpret())
    return from_blockwise(out, layout, (a.shape[0], b.shape[1]))

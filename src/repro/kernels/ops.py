"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy: on TPU the kernels compile natively; elsewhere (this
container is CPU-only) they execute in ``interpret=True`` mode, which runs
the kernel body per grid step in Python — bit-accurate for validation.

These wrappers also adapt between the logical (2-D) world and the blocked
(BWMA) world using :mod:`repro.core.layout`, and carry the accelerator block
size as the layout quantum (the paper's 'governed by the kernel size').
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.blockwise import Blocked
from repro.core.layout import BlockLayout, from_blockwise, to_blockwise
from repro.kernels.bwma_fused_ffn import bwma_fused_ffn
from repro.kernels.bwma_gemm import bwma_gemm
from repro.kernels.bwma_layernorm import bwma_layernorm
from repro.kernels.bwma_softmax import bwma_softmax
from repro.kernels.rwma_gemm import rwma_gemm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("out_dtype",))
def blocked_matmul(a: Blocked, b: Blocked, out_dtype=None) -> Blocked:
    """BWMA GEMM on Blocked values (the paper's accelerated hot loop)."""
    out = bwma_gemm(a.data, b.data, interpret=_interpret())
    if out_dtype is not None:
        out = out.astype(out_dtype)
    return Blocked(out, (a.shape[0], b.shape[1]), a.layout)


@jax.jit
def blocked_softmax(a: Blocked) -> Blocked:
    out = bwma_softmax(a.data, a.shape[1], interpret=_interpret())
    return Blocked(out, a.shape, a.layout)


@jax.jit
def blocked_layernorm(a: Blocked, gamma_blocked, beta_blocked) -> Blocked:
    out = bwma_layernorm(
        a.data, gamma_blocked, beta_blocked, a.shape[1], interpret=_interpret()
    )
    return Blocked(out, a.shape, a.layout)


@functools.partial(jax.jit, static_argnames=("out_dtype",))
def blocked_ffn(a: Blocked, w: Blocked, bias_blocked, out_dtype=None) -> Blocked:
    out = bwma_fused_ffn(a.data, w.data, bias_blocked, interpret=_interpret())
    if out_dtype is not None:
        out = out.astype(out_dtype)
    return Blocked(out, (a.shape[0], w.shape[1]), a.layout)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul_rwma(a: jnp.ndarray, b: jnp.ndarray, bm=128, bk=128, bn=128):
    """Row-major tiled GEMM — the RWMA baseline kernel."""
    return rwma_gemm(a, b, bm=bm, bk=bk, bn=bn, interpret=_interpret())


def matmul_bwma_2d(
    a: jnp.ndarray, b: jnp.ndarray, layout: BlockLayout = BlockLayout(128, 128)
) -> jnp.ndarray:
    """Convenience: 2-D in, 2-D out, blocked internally (conversion at edges
    only — mirrors the paper's whole-model I/O conversion)."""
    ab = to_blockwise(a, BlockLayout(layout.bm, layout.bn))
    bb = to_blockwise(b, BlockLayout(layout.bn, layout.bn))
    out = bwma_gemm(ab, bb, interpret=_interpret())
    return from_blockwise(out, layout, (a.shape[0], b.shape[1]))

"""Fused paged-attention decode + paged-copy kernels (pages ARE tiles).

The engine sizes KV-cache pages to ``cfg.block`` — the accelerator kernel's
native tile — precisely so the serving hot loop can consume them *in place*.
These kernels close that loop.  The reference decode path gathers every
slot's whole logical history into a dense HBM buffer
(``k_pages[page_table]`` — ``max_pages * page`` tokens per slot per layer
per step) before attending; here the grid walks each slot's page-table row
instead and the BlockSpec index map streams exactly one physical page into
VMEM per grid step:

    in_specs=[..., pl.BlockSpec((1, page, ...),
                   lambda b, j, table, seqpos: (table[b, j], 0, 0, 0))]

so the gathered history never exists in HBM at all — the jaxcheck RPJ102
``max_gather_bytes`` budget on the decode step drops from the full gathered
K/V to the token-embedding lookup.  Softmax is accumulated online
(flash-style): per-slot running max / denominator / weighted-value scratch
carried across the sequential page dimension, finalized on the last page.
Keys past a slot's current position (partial-page tails, unmapped null-page
entries, stale pages of retired requests) are masked with the same
``finfo.min`` fill as the reference path, so parity holds to fused-softmax
reassociation (<= 1e-6, the PR-1 BWMA tolerance).

Three kernels:

* :func:`paged_attention_decode` — dense/GQA one-token decode: per-page
  scores via grouped ``dot_general`` (query heads folded onto their KV
  head), online softmax, weighted-V accumulation.
* :func:`mla_paged_attention_decode` — MLA absorbed-matmul decode over
  streamed *latent* pages: scores ``q_lat . c_kv + q_rope . k_rope`` per
  page, accumulating the latent-space output ``o_lat``; the absorption of
  ``q_nope`` through ``W_kv_b`` and the value expansion stay outside (they
  are per-token matmuls, not paged reads).
* :func:`paged_copy` — the COW page copy: one grid step per stacked layer,
  scalar-prefetched ``src``/``dst`` page ids drive the in/out index maps,
  and ``input_output_aliases`` keeps the pool update in place (the donating
  COW jit's aliasing survives, see tests).

All three run compiled on TPU and under ``interpret=True`` elsewhere (CPU
CI exercises the identical grids/BlockSpecs).  They are plain traceable
functions — no inner ``jax.jit`` — so the engine's already-jitted decode /
COW steps inline them without nested-pjit donation hazards.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# the mask fill the reference path uses (repro.models.common.decode_attention
# / attention._mla_absorbed_attend) — also the online-softmax init value, so
# a fully-masked page contributes exp(finfo.min - m) == 0 exactly
_MASK = jnp.finfo(jnp.float32).min


# --------------------------------------------------------------------------
# Dense / GQA paged decode
# --------------------------------------------------------------------------

def _gqa_decode_kernel(table_ref, seqpos_ref, q_ref, k_ref, v_ref, o_ref,
                       acc_ref, m_ref, l_ref, *, page: int, maxp: int,
                       groups: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _MASK)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)        # (H, dh)
    k = k_ref[0].astype(jnp.float32)        # (page, Hkv, dh)
    v = v_ref[0].astype(jnp.float32)
    H, dh = q.shape
    hkv = H // groups
    qg = q.reshape(hkv, groups, dh)
    # per-page grouped scores: (Hkv, g, dh) x (Hkv, dh, page) -> (Hkv, g, page)
    s = jax.lax.dot_general(
        qg, k.transpose(1, 2, 0), (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ) * scale
    # this page covers absolute positions [j*page, (j+1)*page); mask beyond
    # the slot's current token exactly like the reference valid-set
    kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, 1, page), 2)
    s = jnp.where(kpos <= seqpos_ref[b], s, _MASK)
    s = s.reshape(H, page)

    # online (flash-style) softmax update across the page dimension
    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p_att = jnp.exp(s - m_new)              # (H, page)
    l_ref[...] = l_prev * alpha + jnp.sum(p_att, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p_att.reshape(hkv, groups, page), v.transpose(1, 0, 2),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ).reshape(H, dh)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_new

    @pl.when(j == maxp - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)     # unreachable guard (pos 0 is
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)  # always valid)


def paged_attention_decode(q, k_pages, v_pages, page_table, seq_pos, *,
                           scale: Optional[float] = None,
                           interpret: bool = False):
    """Fused one-token GQA decode over the block-paged K/V pool.

    ``q``: (B, 1, H, dh); ``k_pages``/``v_pages``: (num_pages, page, Hkv,
    dh); ``page_table``: (B, max_pages) int32; ``seq_pos``: (B,) int32.
    Returns (B, 1, H, dh) in ``q.dtype`` — the same contract as the
    reference gather + ``decode_attention`` read (write happens outside).
    """
    B, S, H, dh = q.shape
    assert S == 1
    num_pages, page, hkv, _ = k_pages.shape
    maxp = page_table.shape[1]
    scale = dh ** -0.5 if scale is None else scale
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # page_table, seq_pos
        grid=(B, maxp),
        in_specs=[
            pl.BlockSpec((1, H, dh), lambda b, j, t, sp: (b, 0, 0)),
            # ONE physical page per grid step, straight from the table row
            pl.BlockSpec((1, page, hkv, dh),
                         lambda b, j, t, sp: (t[b, j], 0, 0, 0)),
            pl.BlockSpec((1, page, hkv, dh),
                         lambda b, j, t, sp: (t[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, dh), lambda b, j, t, sp: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, dh), jnp.float32),  # weighted-value accumulator
            pltpu.VMEM((H, 1), jnp.float32),   # running max
            pltpu.VMEM((H, 1), jnp.float32),   # running denominator
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _gqa_decode_kernel, page=page, maxp=maxp, groups=H // hkv,
            scale=scale,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, dh), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), seq_pos.astype(jnp.int32),
      q[:, 0], k_pages, v_pages)
    return out[:, None]


# --------------------------------------------------------------------------
# MLA paged decode (absorbed-matmul over streamed latent pages)
# --------------------------------------------------------------------------

def _mla_decode_kernel(table_ref, seqpos_ref, ql_ref, qr_ref, ckv_ref,
                       kr_ref, o_ref, acc_ref, m_ref, l_ref, *, page: int,
                       maxp: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _MASK)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_lat = ql_ref[0].astype(jnp.float32)   # (H, r)
    q_rope = qr_ref[0].astype(jnp.float32)  # (H, dr)
    ckv = ckv_ref[0].astype(jnp.float32)    # (page, r)
    kr = kr_ref[0].astype(jnp.float32)      # (page, dr)
    # absorbed scores against this page's latents: (H, page)
    s = jax.lax.dot_general(
        q_lat, ckv, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    s += jax.lax.dot_general(
        q_rope, kr, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    s *= scale
    kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    s = jnp.where(kpos <= seqpos_ref[b], s, _MASK)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p_att = jnp.exp(s - m_new)              # (H, page)
    l_ref[...] = l_prev * alpha + jnp.sum(p_att, axis=-1, keepdims=True)
    # latent-space output accumulation: (H, page) x (page, r) -> (H, r)
    pv = jax.lax.dot_general(
        p_att, ckv, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_new

    @pl.when(j == maxp - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def mla_paged_attention_decode(q_lat, q_rope, ckv_pages, krope_pages,
                               page_table, seq_pos, *, scale: float,
                               interpret: bool = False):
    """Fused one-token MLA decode over the block-paged *latent* pool.

    ``q_lat``: (B, 1, H, r) — q_nope already absorbed through ``W_kv_b``;
    ``q_rope``: (B, 1, H, dr); ``ckv_pages``: (num_pages, page, r);
    ``krope_pages``: (num_pages, page, dr).  Returns the latent-space
    attention output ``o_lat`` (B, 1, H, r) in ``ckv_pages.dtype`` — the
    caller applies the value expansion (a per-token matmul, not a paged
    read).
    """
    B, S, H, r = q_lat.shape
    assert S == 1
    num_pages, page, _ = ckv_pages.shape
    dr = q_rope.shape[-1]
    maxp = page_table.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # page_table, seq_pos
        grid=(B, maxp),
        in_specs=[
            pl.BlockSpec((1, H, r), lambda b, j, t, sp: (b, 0, 0)),
            pl.BlockSpec((1, H, dr), lambda b, j, t, sp: (b, 0, 0)),
            pl.BlockSpec((1, page, r), lambda b, j, t, sp: (t[b, j], 0, 0)),
            pl.BlockSpec((1, page, dr), lambda b, j, t, sp: (t[b, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, r), lambda b, j, t, sp: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, r), jnp.float32),   # o_lat accumulator
            pltpu.VMEM((H, 1), jnp.float32),   # running max
            pltpu.VMEM((H, 1), jnp.float32),   # running denominator
        ],
    )
    out = pl.pallas_call(
        functools.partial(_mla_decode_kernel, page=page, maxp=maxp,
                          scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, r), ckv_pages.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), seq_pos.astype(jnp.int32),
      q_lat[:, 0], q_rope[:, 0], ckv_pages, krope_pages)
    return out[:, None]


# --------------------------------------------------------------------------
# COW page copy
# --------------------------------------------------------------------------

def _copy_kernel(src_ref, dst_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...]


def paged_copy(pool, src, dst, *, interpret: bool = False):
    """Copy physical page ``src`` -> ``dst`` in one stacked page pool.

    ``pool``: (L, num_pages, page, ...) — any paged leaf (dense K/V or MLA
    latent; the page axis is axis 1 after layer stacking).  The grid is one
    step per stacked layer; scalar-prefetched page ids drive the input and
    output index maps, and ``input_output_aliases`` makes every non-``dst``
    page a true no-op (the engine's donating COW jit keeps its in-place
    aliasing — the pool is never duplicated).  Bit-exact by construction.
    """
    lead = pool.shape[0]
    flat = pool.reshape(lead, pool.shape[1], -1)
    f = flat.shape[-1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # src page id, dst page id (each shape (1,))
        grid=(lead,),
        in_specs=[pl.BlockSpec((1, 1, f), lambda l, s, d: (l, s[0], 0))],
        out_specs=pl.BlockSpec((1, 1, f), lambda l, s, d: (l, d[0], 0)),
    )
    out = pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(flat.shape, flat.dtype),
        # operand indices include the scalar-prefetch args: aliased operand
        # 2 is the pool itself
        input_output_aliases={2: 0},
        interpret=interpret,
    )(jnp.asarray(src, jnp.int32).reshape(1),
      jnp.asarray(dst, jnp.int32).reshape(1), flat)
    return out.reshape(pool.shape)

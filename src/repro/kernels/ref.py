"""Pure-jnp oracles for every Pallas kernel in this package.

Each oracle operates on *logical* (row-major / RWMA) arrays; the kernels
operate on blocked (BWMA) arrays.  Tests block the inputs, run the kernel,
unblock the output and ``assert_allclose`` against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))


def softmax_ref(x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.softmax(x.astype(jnp.float32), axis=-1)


def layernorm_ref(
    x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray, eps: float = 1e-5
) -> jnp.ndarray:
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, -1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, -1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * gamma + beta


def ffn_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Fused GEMM + bias + GELU (paper §3.2 Activation: fused at write-back)."""
    return jax.nn.gelu(matmul_ref(x, w) + b.astype(jnp.float32))

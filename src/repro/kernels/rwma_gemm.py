"""RWMA tiled GEMM — the paper's baseline arrangement, as a Pallas kernel.

Operands are conventional row-major 2-D arrays.  The tiling (grid and block
sizes) is identical to :mod:`repro.kernels.bwma_gemm`; the only difference is
the storage order: here each ``BlockSpec`` step gathers ``bm`` row segments at
stride ``K*esize`` from HBM (a strided DMA descriptor), versus BWMA's single
contiguous burst.  Functionally the two are equivalent — which is the point:
the layout is a pure memory-system optimization.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype)


def rwma_gemm(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = 128,
    bk: int = 128,
    bn: int = 128,
    acc_dtype=jnp.float32,
    interpret: bool = False,
) -> jnp.ndarray:
    """(M, K) @ (K, N) -> (M, N) with row-major (strided-DMA) operands."""
    M, K = a.shape
    K2, N = b.shape
    if K != K2:
        raise ValueError(f"inner dims mismatch: {a.shape} @ {b.shape}")
    if M % bm or K % bk or N % bn:
        raise ValueError(f"shapes {a.shape}x{b.shape} not divisible by blocks")
    out = pl.pallas_call(
        _gemm_kernel,
        grid=(M // bm, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), acc_dtype),
        interpret=interpret,
    )(a, b)
    return out

# repro: noqa-file RPR005 -- CLI driver: the report prints ARE the output
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402  (the two lines above MUST precede any jax import)
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the sharding config is coherent end-to-end:
``jax.jit(step, in_shardings=..., out_shardings=...).lower(...).compile()``
must succeed on the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh,
and we record ``memory_analysis`` (fits?) + ``cost_analysis`` (FLOPs/bytes)
+ the HLO collective schedule for the roofline (EXPERIMENTS.md §Dry-run).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh single multi --out experiments/dryrun
"""
import argparse
import json
import re
import traceback
from typing import Dict

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.configs as C
from repro.analysis.aot import lower_and_compile, memory_record
from repro.configs.shapes import applicable, input_specs
from repro.distributed import axes as AX
from repro.distributed import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    make_decode_step,
    make_prefill_step,
    make_train_step,
    pick_accum_steps,
)
from repro.models import model as M
from repro.optim import OptConfig, adamw_init, cosine_schedule

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(sig: str) -> int:
    """Bytes of one HLO result signature like 'bf16[8,2048,128]'. Tuples:
    sum of elements."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in (post-SPMD) HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = ([^=]+?) (\w[\w\-]*)\(", line)
        if not m:
            continue
        opname = m.group(2)
        for c in _COLLECTIVES:
            if opname == c or opname.startswith(c + "-"):
                out[c] += _shape_bytes(m.group(1))
                out["count"] += 1
                break
    return out


def build_cell(cfg, shape_name: str, mesh):
    """Returns (fn, example_args, in_shardings, out_shardings, donate, info)."""
    info = {"accum": 1}
    spec = input_specs(cfg, shape_name)
    params_shape = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0))
    )
    mode = "serve" if spec["kind"] == "decode" else "train"
    pspecs = SH.param_pspecs(cfg, mesh, params_shape, mode=mode)
    p_shard = SH.named(mesh, pspecs)
    if spec["kind"] == "train":
        import jax.numpy as _jnp
        oc = OptConfig(
            moment_dtype=_jnp.bfloat16 if cfg.param_count() > 1e11
            else _jnp.float32
        )
        opt_shape = jax.eval_shape(lambda p: adamw_init(p, oc), params_shape)
        ospecs = SH.opt_pspecs(cfg, mesh, opt_shape, pspecs)
        o_shard = SH.named(mesh, ospecs)
        bspecs = SH.batch_pspecs(cfg, mesh, spec["batch"])
        b_shard = SH.named(mesh, bspecs)
        from repro.configs.base import SHAPES
        shp = SHAPES[shape_name]
        dp_size = int(np.prod([mesh.shape[a] for a in mesh.axis_names
                               if a != "model"]))
        # §Perf iteration 3 (hillclimbed cell): bigger activation budget =>
        # accum 8 -> 4 => FSDP gather bytes halved for deepseek-v3 multi-pod.
        budget = (8 * 2**30 if (cfg.name == "deepseek-v3-671b"
                                and mesh.size == 512) else 4 * 2**30)
        accum = pick_accum_steps(cfg, shp.global_batch, shp.seq_len, dp_size,
                                 budget_bytes=budget)
        info["accum"] = accum
        fn = make_train_step(cfg, oc, cosine_schedule(3e-4, 100, 10000),
                             accum_steps=accum, grad_pspecs=pspecs)
        args = (params_shape, opt_shape, spec["batch"])
        in_sh = (p_shard, o_shard, b_shard)
        out_sh = (p_shard, o_shard, None)
        donate = (0, 1)
    elif spec["kind"] == "prefill":
        bspecs = SH.batch_pspecs(cfg, mesh, spec["batch"])
        b_shard = SH.named(mesh, bspecs)
        fn = make_prefill_step(cfg)
        args = (params_shape, spec["batch"])
        in_sh = (p_shard, b_shard)
        out_sh = None
        donate = ()
    else:  # decode
        cspecs = SH.cache_pspecs(cfg, mesh, spec["caches"])
        c_shard = SH.named(mesh, cspecs)
        tok_shard = SH.named(
            mesh, SH.batch_pspecs(cfg, mesh, {"tokens": spec["tokens"]})
        )["tokens"]
        pos_shard = NamedSharding(mesh, P())
        fn = make_decode_step(cfg)
        args = (params_shape, spec["caches"], spec["tokens"], spec["pos"])
        in_sh = (p_shard, c_shard, tok_shard, pos_shard)
        out_sh = (None, c_shard)
        donate = (1,)
    return fn, args, in_sh, out_sh, donate, info


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str) -> Dict:
    cfg = C.get_config(arch)
    ok, reason = applicable(cfg, shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    try:
        fn, args, in_sh, out_sh, donate, info = build_cell(cfg, shape_name, mesh)
        with mesh, AX.policy(mesh):
            art = lower_and_compile(
                fn, args, in_shardings=in_sh, out_shardings=out_sh,
                donate_argnums=donate,
            )
            cost = art.cost_analysis()
            coll = collective_bytes(art.hlo_text())
        rec.update(
            status="ok",
            lower_s=round(art.lower_s, 1),
            compile_s=round(art.compile_s, 1),
            n_devices=mesh.size,
            memory=memory_record(art.compiled),
            flops=float(cost.get("flops", -1)) if cost else -1,
            bytes_accessed=float(cost.get("bytes accessed", -1)) if cost else -1,
            collectives=coll,
            params=cfg.param_count(),
            active_params=cfg.active_param_count(),
            accum_steps=info.get("accum", 1),
        )
    except Exception as e:  # record the failure — failures here are bugs
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch}_{shape_name}_{mesh_kind}.json".replace("/", "-")
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="+", default=["all"])
    ap.add_argument("--shape", nargs="+", default=["all"])
    ap.add_argument("--mesh", nargs="+", default=["single", "multi"],
                    choices=["single", "multi"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    archs = C.arch_ids() if args.arch == ["all"] else args.arch
    shapes = list(C.SHAPES) if args.shape == ["all"] else args.shape
    n_ok = n_err = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in args.mesh:
                rec = run_cell(arch, shape, mesh_kind, args.out)
                status = rec["status"]
                n_ok += status == "ok"
                n_err += status == "error"
                n_skip += status == "skipped"
                msg = rec.get("error", rec.get("reason", ""))
                extra = ""
                if status == "ok":
                    mem_gb = rec["memory"].get("argument_size_in_bytes", 0) / 2**30
                    extra = (f"args={mem_gb:.2f}GiB/dev "
                             f"temp={rec['memory'].get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
                             f"lower={rec['lower_s']}s compile={rec['compile_s']}s")
                print(f"[{status:7s}] {arch} x {shape} x {mesh_kind} {extra}{msg}",
                      flush=True)
    print(f"done: {n_ok} ok, {n_err} errors, {n_skip} skipped")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

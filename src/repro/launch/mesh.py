"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (v5e pod).
Multi-pod: 2 pods x 256 = 512 chips with a leading "pod" axis (DCN).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist (tests / examples on CPU): (data=1, model=n)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def make_serve_mesh(spec: str):
    """Parse a ``--mesh DxM`` spec (e.g. ``1x4``) into a (data, model) mesh.

    ``D`` is the data axis (replicated serving replicas), ``M`` the model
    (tensor-parallel) axis the KV pools and weights shard over.  Needs
    ``D*M`` visible devices — on a single host, simulate with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before jax
    initializes).
    """
    parts = spec.lower().split("x")
    if len(parts) != 2 or not all(p.isdigit() for p in parts):
        raise ValueError(f"--mesh expects DxM (e.g. 1x4), got {spec!r}")
    d, m = int(parts[0]), int(parts[1])
    if d < 1 or m < 1:
        raise ValueError(f"--mesh axes must be >= 1, got {spec!r}")
    n = len(jax.devices())
    if d * m > n:
        raise ValueError(
            f"--mesh {spec} needs {d * m} devices but only {n} are visible; "
            f"simulate with XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{d * m} (must be set before jax initializes)"
        )
    return jax.make_mesh((d, m), ("data", "model"))

# repro: noqa-file RPR005 -- CLI driver: the report prints ARE the output
"""Serving entry point: continuous batching over the block-paged KV cache.

Multi-request workload (Poisson-ish staggered arrivals, fixed seeds):

  PYTHONPATH=src python -m repro.launch.serve --arch minicpm-2b --smoke \
      --num-requests 6 --max-seqs 2 --prompt-len 12 --max-new 16 \
      --mean-interarrival 4 --page-size 8

MLA (DeepSeek-V3-style) serves through latent pages; enc-dec (whisper)
through immutable per-slot cross rows + paged decoder self-attention:

  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-v3-671b \
      --smoke --num-requests 6 --max-seqs 2 --prompt-len 8 --max-new 12
  PYTHONPATH=src python -m repro.launch.serve --arch whisper-tiny --smoke \
      --num-requests 6 --max-seqs 2 --prompt-len 8 --max-new 12

``--backend pallas`` serves the paged decode + COW path through the fused
Pallas kernels (compiled on TPU, interpret mode elsewhere):

  PYTHONPATH=src python -m repro.launch.serve --arch minicpm-2b --smoke \
      --num-requests 6 --max-seqs 2 --backend pallas

``--mesh DxM`` serves tensor-parallel over a ``(data, model)`` device mesh:
resident sharded weights, head-sharded KV pools, sharded jitted steps
(simulate on CPU with ``XLA_FLAGS=--xla_force_host_platform_device_count=2``):

  PYTHONPATH=src python -m repro.launch.serve --arch minicpm-2b --smoke \
      --num-requests 6 --max-seqs 2 --mesh 1x2

Legacy single-wave batched generation (also the only path for the vision
frontend, which the adapter registry does not cover yet):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-vl-72b --smoke \
      --batch 4 --prompt-len 16 --max-new 32
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.models import adapters as A
from repro.models import model as M
from repro.serve import (
    Engine,
    EngineConfig,
    ServeConfig,
    Server,
    build_serve_report,
    frontend_extras,
    make_requests,
    run_static_waves,
)


def run_single_wave(cfg, params, args, mesh=None):
    """Legacy path: one batch, one wave (works for every cache family)."""
    srv = Server(
        cfg, params,
        ServeConfig(max_len=args.prompt_len + args.max_new + 8,
                    temperature=args.temperature),
        mesh=mesh,
    )
    toks = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    batch = frontend_extras(cfg, {"tokens": toks}, args.batch, args.prompt_len)
    t0 = time.time()
    out = srv.generate(batch, max_new_tokens=args.max_new)
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({out.size / dt:.1f} tok/s incl. compile)")
    print(out[:, :16])


def run_workload(cfg, params, args, mesh=None):
    """Multi-request workload through the selected engine(s)."""
    reqs = make_requests(
        cfg.vocab_size, args.num_requests,
        prompt_len=args.prompt_len, max_new=args.max_new,
        mean_interarrival=args.mean_interarrival, seed=args.seed,
    )
    max_len = args.prompt_len + args.max_new + 1
    useful = sum(r["max_new_tokens"] for r in reqs)

    if args.engine in ("static", "both"):
        srv = Server(cfg, params, ServeConfig(
            max_len=max_len, temperature=args.temperature, seed=args.seed,
        ), mesh=mesh)
        t0 = time.time()
        outs = run_static_waves(srv, reqs, args.max_seqs)
        dt = time.time() - t0
        print(f"[static-wave]  {len(outs)} requests, {useful} tokens in "
              f"{dt:.2f}s -> {useful / dt:.1f} tok/s (incl. compile)")

    if args.engine in ("continuous", "both"):
        eng = Engine(cfg, params, EngineConfig(
            max_seqs=args.max_seqs, max_len=max_len,
            page_size=args.page_size, num_pages=args.num_pages,
            temperature=args.temperature, seed=args.seed,
            chunked_prefill=not args.no_chunked_prefill,
            prefill_chunk=args.prefill_chunk,
            prefill_tokens_per_step=args.prefill_tokens_per_step,
            prefill_chunks_per_step=args.prefill_chunks_per_step,
            prefix_sharing=not args.no_prefix_sharing,
            backend=args.backend,
            debug_audit=args.debug_audit,
            obs=args.obs,
        ), mesh=mesh)
        for r in reqs:
            eng.submit(r["prompt"], r["max_new_tokens"],
                       rid=r["rid"], arrival_step=r["arrival_step"])
        t0 = time.time()
        done = eng.run()
        dt = time.time() - t0
        # one report, two renderings: the human table below prints straight
        # from this dict, and --json-report dumps the same dict to disk
        report = build_serve_report(eng, done, wall_s=dt, useful_tokens=useful)
        print_continuous_report(eng, report)
        if args.json_report:
            with open(args.json_report, "w", encoding="utf-8") as f:
                json.dump(report, f, indent=2)
            print(f"  json report -> {args.json_report}")
        if args.trace_out:
            trace = eng.export_trace(args.trace_out)
            print(f"  chrome trace -> {args.trace_out} "
                  f"({len(trace['traceEvents'])} events; open in "
                  f"ui.perfetto.dev or chrome://tracing)")


def print_continuous_report(eng, report):
    """Render the machine-readable serve report as the human table."""
    e, pool, px, wl = (report["engine"], report["pool"],
                       report["prefix_cache"], report["workload"])
    mode = (f"chunked prefill (chunk={e['chunk_size']} tok, "
            f"budget={e['prefill_tokens_per_step']} tok/step)"
            if e["chunked_prefill"] else "one-shot prefill")
    print(f"[continuous]   {wl['num_requests']} requests, "
          f"{wl['useful_tokens']} tokens in "
          f"{wl['wall_s']:.2f}s -> {wl['tok_s']:.1f} tok/s (incl. compile); "
          f"page={pool['page_size']} pool={pool['pages_total'] + 1} "
          f"cache={pool['cache_mb']:.2f} MB, {mode}")
    print("  rid arrive admit queue ttft_ms preempt cached  tok/s  n_tok")
    for r in report["requests"]:
        tok_s = float("inf") if r["decode_tok_s"] is None else r["decode_tok_s"]
        ttft_ms = float("nan") if r["ttft_ms"] is None else r["ttft_ms"]
        print(f"  {r['rid']:3d} {r['arrival_step']:6d} {r['admitted_step']:5d} "
              f"{r['queue_steps']:5d} {ttft_ms:7.1f} "
              f"{r['preemptions']:7d} {r['cached_prompt_tokens']:6d} "
              f"{tok_s:6.1f} {r['n_tokens']:6d}")
    print(f"  engine steps={e['steps']} decode_steps={e['decode_steps']} "
          f"prefill_tokens={e['prefill_tokens']} "
          f"prefill_chunks={e['prefill_chunks']}")
    if px["enabled"]:
        label = (px["mode"] if px["mode"] == "compute-skipping"
                 else "memory-dedup, recompute")
        print(f"  prefix cache [{label}]: {px['cached_prompt_tokens']}"
              f"/{px['prompt_tokens']} prompt tokens served from cache "
              f"({100.0 * px['hit_rate']:.1f}% hit rate), "
              f"{pool['pages_aliased_total']} page aliases, "
              f"{pool['cow_copies_total']} COW copies, "
              f"{pool['prefix_cache_pages']} pages resident")
    else:
        print("  prefix cache: off (family not shareable or "
              "--no-prefix-sharing)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=C.arch_ids())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="legacy single-wave batch size (--num-requests 0)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--num-requests", type=int, default=0,
                    help="> 0 switches to the multi-request workload path")
    ap.add_argument("--engine", choices=("static", "continuous", "both"),
                    default="continuous")
    ap.add_argument("--max-seqs", type=int, default=4,
                    help="concurrent batch slots (workload path)")
    ap.add_argument("--mean-interarrival", type=float, default=4.0,
                    help="mean request inter-arrival gap in decode steps")
    ap.add_argument("--page-size", type=int, default=0,
                    help="KV page size in tokens; 0 derives from cfg.block")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="physical page pool size; 0 sizes for max_seqs")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked-admission chunk in tokens; 0 derives one "
                         "page (SSD-grid-aligned for SSM models)")
    ap.add_argument("--prefill-tokens-per-step", type=int, default=0,
                    help="prompt tokens admitted per engine step before the "
                         "decode batch steps (page-granular; the "
                         "latency/throughput knob).  0 derives from the "
                         "deprecated --prefill-chunks-per-step alias")
    ap.add_argument("--prefill-chunks-per-step", type=int, default=None,
                    help="DEPRECATED alias: admission budget as a chunk "
                         "count (use --prefill-tokens-per-step; setting "
                         "this emits a one-shot DeprecationWarning)")
    ap.add_argument("--no-chunked-prefill", action="store_true",
                    help="one-shot prefill per admission (the pre-chunking "
                         "behavior; still installed via donating jit)")
    ap.add_argument("--backend", default="reference",
                    choices=("reference", "pallas"),
                    help="paged-decode path for the continuous engine: the "
                         "jnp gather oracle or the fused paged-attention / "
                         "COW kernels (compiled on TPU, interpret mode "
                         "elsewhere; families without paged decode fall "
                         "back to their reference path)")
    ap.add_argument("--mesh", default="",
                    help="DxM device mesh (e.g. 1x2): serve tensor-parallel "
                         "— resident sharded weights, head-sharded KV pools, "
                         "sharded jitted steps.  Needs D*M visible devices "
                         "(simulate on CPU with XLA_FLAGS=--xla_force_host_"
                         "platform_device_count=N); '' serves single-device")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable the shared-prefix page cache (radix "
                         "index + refcounted aliasing + copy-on-write); "
                         "stateful families disable it automatically")
    ap.add_argument("--obs", action="store_true",
                    help="deep observability: audit-backed pool gauges every "
                         "step + jax.profiler.TraceAnnotation around the "
                         "jitted decode/chunk steps (spans and counters are "
                         "always recorded; this only deepens collection)")
    ap.add_argument("--json-report", default="",
                    help="write the latency/prefix-cache report (the table "
                         "above, machine-readable, plus the full metrics "
                         "registry snapshot) as JSON to this path")
    ap.add_argument("--trace-out", default="",
                    help="export request-lifecycle spans and engine-step "
                         "tracks as Chrome-trace JSON (open in "
                         "ui.perfetto.dev); validate with "
                         "`python -m repro.serve.obs PATH`")
    ap.add_argument("--debug-audit", action="store_true",
                    help="run the paged-KV refcount auditor after every "
                         "engine step (slow; catches page leaks / double "
                         "frees at the step that introduces them)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.prefill_chunks_per_step is not None:
        # one-shot per process; the Engine would also warn, but the flag
        # deserves the notice even on paths that never build an Engine
        from repro.serve.engine import warn_prefill_chunks_deprecated
        warn_prefill_chunks_deprecated()

    cfg = C.get_config(args.arch, smoke=args.smoke,
                       dtype=jnp.float32 if args.smoke else jnp.bfloat16)
    if args.num_requests > 0 and args.engine != "static":
        # refuse BEFORE any pool (or even params) is allocated, with the
        # exact family list the adapter registry reports
        msg = A.unsupported_message(cfg, hint="rerun with --engine static")
        if msg is not None:
            raise SystemExit(msg)
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_serve_mesh
        mesh = make_serve_mesh(args.mesh)
        print(f"serving on mesh {args.mesh}: "
              f"{mesh.shape['data']} data x {mesh.shape['model']} model")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    if args.num_requests > 0:
        run_workload(cfg, params, args, mesh=mesh)
    else:
        run_single_wave(cfg, params, args, mesh=mesh)


if __name__ == "__main__":
    main()

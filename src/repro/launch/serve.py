# repro: noqa-file RPR005 -- CLI driver: the report prints ARE the output
"""Serving entry point: continuous batching over the block-paged KV cache.

Multi-request workload (Poisson-ish staggered arrivals, fixed seeds):

  PYTHONPATH=src python -m repro.launch.serve --arch minicpm-2b --smoke \
      --num-requests 6 --max-seqs 2 --prompt-len 12 --max-new 16 \
      --mean-interarrival 4 --page-size 8

MLA (DeepSeek-V3-style) serves through latent pages; enc-dec (whisper)
through immutable per-slot cross rows + paged decoder self-attention:

  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-v3-671b \
      --smoke --num-requests 6 --max-seqs 2 --prompt-len 8 --max-new 12
  PYTHONPATH=src python -m repro.launch.serve --arch whisper-tiny --smoke \
      --num-requests 6 --max-seqs 2 --prompt-len 8 --max-new 12

Legacy single-wave batched generation (also the only path for the vision
frontend, which the adapter registry does not cover yet):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-vl-72b --smoke \
      --batch 4 --prompt-len 16 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.models import adapters as A
from repro.models import model as M
from repro.serve import (
    Engine,
    EngineConfig,
    ServeConfig,
    Server,
    frontend_extras,
    make_requests,
    run_static_waves,
)


def run_single_wave(cfg, params, args):
    """Legacy path: one batch, one wave (works for every cache family)."""
    srv = Server(
        cfg, params,
        ServeConfig(max_len=args.prompt_len + args.max_new + 8,
                    temperature=args.temperature),
    )
    toks = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    batch = frontend_extras(cfg, {"tokens": toks}, args.batch, args.prompt_len)
    t0 = time.time()
    out = srv.generate(batch, max_new_tokens=args.max_new)
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({out.size / dt:.1f} tok/s incl. compile)")
    print(out[:, :16])


def run_workload(cfg, params, args):
    """Multi-request workload through the selected engine(s)."""
    reqs = make_requests(
        cfg.vocab_size, args.num_requests,
        prompt_len=args.prompt_len, max_new=args.max_new,
        mean_interarrival=args.mean_interarrival, seed=args.seed,
    )
    max_len = args.prompt_len + args.max_new + 1
    useful = sum(r["max_new_tokens"] for r in reqs)

    if args.engine in ("static", "both"):
        srv = Server(cfg, params, ServeConfig(
            max_len=max_len, temperature=args.temperature, seed=args.seed,
        ))
        t0 = time.time()
        outs = run_static_waves(srv, reqs, args.max_seqs)
        dt = time.time() - t0
        print(f"[static-wave]  {len(outs)} requests, {useful} tokens in "
              f"{dt:.2f}s -> {useful / dt:.1f} tok/s (incl. compile)")

    if args.engine in ("continuous", "both"):
        eng = Engine(cfg, params, EngineConfig(
            max_seqs=args.max_seqs, max_len=max_len,
            page_size=args.page_size, num_pages=args.num_pages,
            temperature=args.temperature, seed=args.seed,
            chunked_prefill=not args.no_chunked_prefill,
            prefill_chunk=args.prefill_chunk,
            prefill_tokens_per_step=args.prefill_tokens_per_step,
            prefill_chunks_per_step=args.prefill_chunks_per_step,
            prefix_sharing=not args.no_prefix_sharing,
            debug_audit=args.debug_audit,
        ))
        for r in reqs:
            eng.submit(r["prompt"], r["max_new_tokens"],
                       rid=r["rid"], arrival_step=r["arrival_step"])
        t0 = time.time()
        done = eng.run()
        dt = time.time() - t0
        mode = ("chunked prefill "
                f"(chunk={eng.chunk_size} tok, "
                f"budget={eng.tokens_per_step} tok/step)"
                if eng.ec.chunked_prefill else "one-shot prefill")
        print(f"[continuous]   {len(done)} requests, {useful} tokens in "
              f"{dt:.2f}s -> {useful / dt:.1f} tok/s (incl. compile); "
              f"page={eng.kv.page_size} pool={eng.kv.allocator.num_pages} "
              f"cache={eng.kv.cache_bytes() / 1e6:.2f} MB, {mode}")
        print("  rid arrive admit queue ttft_ms preempt cached  tok/s  n_tok")
        for r in done:
            s = r.stats
            print(f"  {r.rid:3d} {s.arrival_step:6d} {s.admitted_step:5d} "
                  f"{s.queue_steps:5d} {s.ttft_s * 1e3:7.1f} "
                  f"{s.n_preemptions:7d} {s.cached_prompt_tokens:6d} "
                  f"{s.decode_tok_s(len(r.out_tokens)):6.1f} "
                  f"{len(r.out_tokens):6d}")
        print(f"  engine steps={eng.step_count} decode_steps={eng.decode_steps} "
              f"prefill_tokens={eng.prefill_tokens} "
              f"prefill_chunks={eng.prefill_chunks}")
        prompt_toks = sum(r.prompt_len for r in done)
        cached = sum(r.stats.cached_prompt_tokens for r in done)
        if eng.kv.sharing:
            mode = ("compute-skipping" if eng.kv.skip_prefill
                    else "memory-dedup, recompute")
            print(f"  prefix cache [{mode}]: {cached}/{prompt_toks} prompt "
                  f"tokens served from cache "
                  f"({100.0 * cached / max(prompt_toks, 1):.1f}% hit rate), "
                  f"{eng.kv.pages_aliased} page aliases, "
                  f"{eng.kv.cow_copies} COW copies, "
                  f"{eng.kv.prefix_cache_pages} pages resident")
        else:
            print("  prefix cache: off (family not shareable or "
                  "--no-prefix-sharing)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=C.arch_ids())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="legacy single-wave batch size (--num-requests 0)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--num-requests", type=int, default=0,
                    help="> 0 switches to the multi-request workload path")
    ap.add_argument("--engine", choices=("static", "continuous", "both"),
                    default="continuous")
    ap.add_argument("--max-seqs", type=int, default=4,
                    help="concurrent batch slots (workload path)")
    ap.add_argument("--mean-interarrival", type=float, default=4.0,
                    help="mean request inter-arrival gap in decode steps")
    ap.add_argument("--page-size", type=int, default=0,
                    help="KV page size in tokens; 0 derives from cfg.block")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="physical page pool size; 0 sizes for max_seqs")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked-admission chunk in tokens; 0 derives one "
                         "page (SSD-grid-aligned for SSM models)")
    ap.add_argument("--prefill-tokens-per-step", type=int, default=0,
                    help="prompt tokens admitted per engine step before the "
                         "decode batch steps (page-granular; the "
                         "latency/throughput knob).  0 derives from the "
                         "deprecated --prefill-chunks-per-step alias")
    ap.add_argument("--prefill-chunks-per-step", type=int, default=None,
                    help="DEPRECATED alias: admission budget as a chunk "
                         "count (use --prefill-tokens-per-step; setting "
                         "this emits a one-shot DeprecationWarning)")
    ap.add_argument("--no-chunked-prefill", action="store_true",
                    help="one-shot prefill per admission (the pre-chunking "
                         "behavior; still installed via donating jit)")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable the shared-prefix page cache (radix "
                         "index + refcounted aliasing + copy-on-write); "
                         "stateful families disable it automatically")
    ap.add_argument("--debug-audit", action="store_true",
                    help="run the paged-KV refcount auditor after every "
                         "engine step (slow; catches page leaks / double "
                         "frees at the step that introduces them)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.prefill_chunks_per_step is not None:
        # one-shot per process; the Engine would also warn, but the flag
        # deserves the notice even on paths that never build an Engine
        from repro.serve.engine import warn_prefill_chunks_deprecated
        warn_prefill_chunks_deprecated()

    cfg = C.get_config(args.arch, smoke=args.smoke,
                       dtype=jnp.float32 if args.smoke else jnp.bfloat16)
    if args.num_requests > 0 and args.engine != "static":
        # refuse BEFORE any pool (or even params) is allocated, with the
        # exact family list the adapter registry reports
        msg = A.unsupported_message(cfg, hint="rerun with --engine static")
        if msg is not None:
            raise SystemExit(msg)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    if args.num_requests > 0:
        run_workload(cfg, params, args)
    else:
        run_single_wave(cfg, params, args)


if __name__ == "__main__":
    main()

"""Serving entry point: batched generation with the family-specific cache.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --smoke \
      --batch 4 --prompt-len 16 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.serve import ServeConfig, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=C.arch_ids())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = C.get_config(args.arch, smoke=args.smoke,
                       dtype=jnp.float32 if args.smoke else jnp.bfloat16)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    srv = Server(
        cfg, params,
        ServeConfig(max_len=args.prompt_len + args.max_new + 8,
                    temperature=args.temperature),
    )
    toks = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    batch = {"tokens": toks}
    if cfg.frontend == "vision":
        batch["vis_embeds"] = jnp.zeros(
            (args.batch, cfg.n_frontend_tokens, cfg.d_model), cfg.dtype
        )
        batch["positions3"] = jnp.broadcast_to(
            jnp.arange(args.prompt_len, dtype=jnp.int32)[None, None],
            (3, args.batch, args.prompt_len),
        )
    if cfg.frontend == "audio":
        batch["audio_embeds"] = jnp.zeros(
            (args.batch, cfg.encoder_seq, cfg.d_model), cfg.dtype
        )
    t0 = time.time()
    out = srv.generate(batch, max_new_tokens=args.max_new)
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({out.size / dt:.1f} tok/s incl. compile)")
    print(out[:, :16])


if __name__ == "__main__":
    main()

"""Step functions: train / prefill / decode, shared by dryrun + entry points."""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.optim import OptConfig, adamw_update


def split_microbatches(batch: Dict, accum: int) -> Dict:
    """(B, ...) -> (accum, B/accum, ...); positions3 keeps its leading 3."""
    out = {}
    for k, v in batch.items():
        if k == "positions3":  # (3, B, S)
            b = v.shape[1] // accum
            out[k] = jnp.moveaxis(
                v.reshape(3, accum, b, *v.shape[2:]), 0, 1
            )  # (accum, 3, b, S)
        else:
            b = v.shape[0] // accum
            out[k] = v.reshape(accum, b, *v.shape[1:])
    return out


def make_train_step(
    cfg: ModelConfig, oc: OptConfig, lr_fn: Callable, *, accum_steps: int = 1,
    grad_pspecs=None,
):
    """AdamW train step with optional gradient accumulation.

    ``accum_steps > 1`` runs the global batch as a scan over microbatches,
    accumulating fp32 grads — per-device live activations shrink by the same
    factor (how the 70-670B train shapes fit HBM) at the cost of one more
    grad-sized buffer.
    """

    def train_step(params, opt_state, batch):
        def lf(p, b):
            return M.loss_fn(cfg, p, b)

        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(
                params, batch
            )
        else:
            micro = split_microbatches(batch, accum_steps)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            if grad_pspecs is not None:
                # pin the f32 accumulation buffer to the parameter sharding;
                # without this GSPMD can replicate the EP expert grads.
                from jax.sharding import PartitionSpec as _P
                g0 = jax.tree.map(
                    lambda g, s: jax.lax.with_sharding_constraint(g, s),
                    g0, grad_pspecs,
                    is_leaf=lambda x: isinstance(x, _P),
                )

            def body(carry, mb):
                acc, loss_acc = carry
                (lv, met), g = jax.value_and_grad(lf, has_aux=True)(params, mb)
                acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32) / accum_steps,
                    acc, g,
                )
                return (acc, loss_acc + lv / accum_steps), met

            (grads, loss), metrics = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), micro
            )
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        lr_now = lr_fn(opt_state["step"])
        new_params, new_opt = adamw_update(grads, opt_state, params, oc, lr_now)
        out = {"loss": loss, "lr": lr_now}
        out.update(metrics)
        return new_params, new_opt, out

    return train_step


def pick_accum_steps(cfg: ModelConfig, global_batch: int, seq: int,
                     dp_size: int, budget_bytes: float = 4 * 2**30) -> int:
    """Choose accumulation so the per-device layer-input stack (the dominant
    remat residual: B_loc*S*d*2*L bytes) fits the activation budget.

    The default 4 GiB budget favours small microbatches (cheap activations,
    more FSDP gathers); §Perf iteration 3 raises it to 8 GiB for the
    deepseek-v3 multi-pod cell where the gather term dominates — the
    launcher passes the per-cell override."""
    b_loc = max(1, global_batch // dp_size)
    est = b_loc * seq * cfg.d_model * 2 * cfg.n_layers
    accum = 1
    while est / accum > budget_bytes and accum < global_batch // dp_size:
        accum *= 2
    return min(accum, max(1, global_batch // dp_size))


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return M.prefill(cfg, params, batch)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, caches, tokens, pos):
        return M.decode_step(cfg, params, caches, tokens, pos)

    return decode_step

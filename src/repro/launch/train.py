# repro: noqa-file RPR005 -- CLI driver: the report prints ARE the output
"""Training entry point.

  PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --smoke \
      --steps 100 --batch 8 --seq 64 --ckpt /tmp/ckpt

On a real cluster this binary runs per host (jax.distributed.initialize) and
``--mesh single|multi`` selects the production mesh; on this CPU container
use --smoke (reduced config, local mesh).
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp

import repro.configs as C
from repro.data import SyntheticLMData
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.optim import OptConfig, wsd_schedule
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=C.arch_ids())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=["local", "single", "multi"],
                    default="local")
    ap.add_argument("--wsd", action="store_true",
                    help="WSD schedule (MiniCPM) instead of cosine")
    ap.add_argument("--grad-compression", choices=["int8"], default=None)
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-step straggler deadline (s)")
    args = ap.parse_args()

    cfg = C.get_config(args.arch, smoke=args.smoke,
                       dtype=jnp.float32 if args.smoke else jnp.bfloat16)
    mesh = (make_local_mesh() if args.mesh == "local"
            else make_production_mesh(multi_pod=args.mesh == "multi"))
    lr_fn = None
    if args.wsd:
        lr_fn = wsd_schedule(args.lr, args.steps // 10, args.steps * 7 // 10,
                             args.steps // 5)
    tc = TrainerConfig(
        steps=args.steps, accum_steps=args.accum,
        checkpoint_every=args.ckpt_every, checkpoint_dir=args.ckpt,
        step_deadline_s=args.deadline, grad_compression=args.grad_compression,
    )
    tr = Trainer(cfg, mesh, tc, OptConfig(lr=args.lr), lr_fn=lr_fn)
    data = SyntheticLMData(cfg, global_batch=args.batch, seq_len=args.seq)
    params, opt, hist = tr.fit(data)
    print(f"final loss: {hist[-1]['loss']:.4f}"
          f" (start {hist[0]['loss']:.4f})")
    if tr.straggler_events:
        print(f"straggler events: {len(tr.straggler_events)}")


if __name__ == "__main__":
    main()

"""CacheAdapter: one paged-cache protocol implementation per layer family.

The continuous-batching engine (:mod:`repro.serve`) stores decode context in
the units the accelerator kernel consumes — pages of ``cfg.block`` token
slots.  What a *page of context* means differs per layer family:

* full-attention dense/GQA layers page the K/V tensors themselves,
* MLA layers page the tiny latent ``c_kv`` + shared rotary key (the point
  of MLA: the latent is what the absorbed-matmul decode consumes),
* SWA layers keep an O(window) ring row per batch slot,
* SSM layers keep an O(1) state row per batch slot,
* encoder-decoder cross-attention keeps an immutable encoder-side K/V row
  per slot, installed once at admission.

Each family implements :class:`CacheAdapter`: pool shapes, the donated
prefill install, the chunked-prefill step, the per-slot decode step, and
the active-mask semantics that keep a lockstep batch step from corrupting
slots it does not own.  The engine, scheduler and model layers drive
adapters generically through :func:`adapters_for` — this module is the ONLY
place that knows which family uses which cache layout.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.backend import resolve_backend
from repro.models import attention as attn
from repro.models import ssm as ssmm


# --------------------------------------------------------------------------
# Segment structure (which layer kinds a config stacks, and how many)
# --------------------------------------------------------------------------

def layer_segments(cfg: ModelConfig) -> List[Tuple[str, int]]:
    """Homogeneous layer groups, each scanned with stacked params."""
    if cfg.family == "ssm":
        return [("ssm", cfg.n_layers)]
    if cfg.family == "hybrid":
        return [("hybrid", cfg.n_layers)]
    if cfg.family == "moe":
        segs = []
        if cfg.first_k_dense:
            segs.append(("dense", cfg.first_k_dense))
        segs.append(("moe", cfg.n_layers - cfg.first_k_dense))
        return segs
    return [("dense", cfg.n_layers)]  # dense / vlm / encdec decoder


@dataclasses.dataclass(frozen=True)
class CacheGeometry:
    """Sizing of the engine's cache pools (tokens are page-granular)."""

    max_seqs: int
    num_pages: int
    page_size: int
    max_len: int


# --------------------------------------------------------------------------
# Shared slot-row helpers (per-slot, non-paged layouts)
# --------------------------------------------------------------------------

def read_slot_rows(seg_cache: Dict, slot) -> Dict:
    """Extract one batch slot's rows as a (1, ...) pytree (traced slot id)."""
    return {
        k: jax.lax.dynamic_slice_in_dim(v, slot, 1, axis=0)
        for k, v in seg_cache.items()
    }


def write_slot_rows(seg_cache: Dict, rows: Dict, slot, *, axis: int = 0) -> Dict:
    """Scatter one slot's rows back into the per-slot cache arrays.

    ``axis`` is the slot axis: 0 inside a layer step (the leading L axis is
    scanned away), 1 for install into the full (L, max_seqs, ...) pools.
    """
    return {
        k: jax.lax.dynamic_update_slice_in_dim(
            seg_cache[k], rows[k].astype(seg_cache[k].dtype), slot, axis
        )
        for k in seg_cache
    }


def _install_paged(dst: Dict, src: Dict, phys_tok, off_tok,
                   names: Dict[str, str]) -> Dict:
    """Scatter (L, S)-shaped prefill tensors per token into physical pages.

    ``names`` maps prefill-cache keys to pool keys (e.g. ``k -> k_pages``).
    Tokens past the slot's allocation arrive mapped to the null page (the
    bucketed-prefill pad tail), whose content is garbage by design.
    """
    out = dict(dst)
    for s_name, p_name in names.items():
        x = src[s_name][:, 0]  # (L, S, ...)
        out[p_name] = dst[p_name].at[:, phys_tok, off_tok].set(
            x.astype(dst[p_name].dtype)
        )
    return out


# --------------------------------------------------------------------------
# The protocol
# --------------------------------------------------------------------------

class CacheAdapter:
    """One layer family's share of the engine cache.

    ``key`` is the segment-cache entry the adapter owns; ``param_key`` the
    layer-parameter subtree that drives it.  ``paged`` adapters draw on the
    shared physical page pool (page accounting in the allocator covers
    them); non-paged adapters own ``max_seqs`` per-slot rows.
    """

    key: str = ""
    param_key: str = ""
    family: str = ""  # human name the registry reports
    paged: bool = False
    # prefix sharing capability: a shareable adapter's cache entries are
    # position-indexed pages whose content is a pure function of the token
    # prefix, so physical pages may be aliased across requests (PagedAttn,
    # LatentMLA).  Slot-local rows (SWA rings, SSM states) and per-request
    # side-input caches (enc-dec cross rows) declare False.
    shareable: bool = False
    # True when the family's cache content depends on per-request inputs
    # beyond the token ids (enc-dec audio): the whole stack's hidden states
    # are then request-specific and token-keyed page aliasing is unsound
    # for EVERY co-resident adapter, not just this one.
    side_inputs: bool = False

    def copy_page(self, cfg: ModelConfig, seg_cache: Dict, src, dst) -> Dict:
        """Copy physical page ``src`` -> ``dst`` in this adapter's pools
        (the COW step; traced inside the engine's donating copy jit).
        Only meaningful for paged adapters."""
        raise NotImplementedError

    def pool_pspecs(self, cfg: ModelConfig, *, tp_axis: str = "model",
                    tp_size: int = 1) -> Dict:
        """PartitionSpec per **L-stacked** pool leaf for tensor-parallel
        serving (``{pool_name: PartitionSpec}``; missing names replicate).

        Specs describe the engine pools AFTER layer stacking (leading L
        axis, see :func:`repro.models.model.init_paged_cache`).  Page ids,
        page tables and free lists are host/replicated state and never
        appear here.  The base adapter replicates everything — families
        whose pools carry a kv-head axis override to shard it over the
        model axis when it divides, which is the mesh-parallel half of the
        paper's arrangement claim: each core streams only its own heads'
        pages.
        """
        return {}

    def chunk_multiple(self, cfg: ModelConfig) -> int:
        """Prefill chunk boundaries must sit on multiples of this."""
        return 1

    def init_pool(self, cfg: ModelConfig, geom: CacheGeometry) -> Dict:
        """One layer's share of the engine cache (pre L-stacking)."""
        raise NotImplementedError

    def install(self, cfg: ModelConfig, dst: Dict, src: Dict, slot,
                phys_tok, off_tok) -> Dict:
        """Write one request's one-shot prefill cache into its slot
        (traced inside the engine's donating install jit)."""
        raise NotImplementedError

    def src_tokens(self, src: Dict) -> Optional[int]:
        """Token count of a (possibly padded) paged prefill source — the
        host needs it to build per-token page targets.  None: not paged."""
        return None

    def chunk(self, p: Dict, cfg: ModelConfig, h, positions, cache: Dict,
              ctx: Dict, pos_offset):
        """One prompt chunk of one slot.  ``ctx`` carries {slot, first,
        table_row, phys_tok, off_tok}.  Returns (mixer_out, new_cache)."""
        raise NotImplementedError

    def decode(self, p: Dict, cfg: ModelConfig, h, positions, cache: Dict,
               *, seq_pos, page_table, active):
        """One lockstep decode step, every slot at its own position.
        Inactive slots' cache writes must be dropped (null page / OOB
        index / where-mask).  Returns (mixer_out, new_cache)."""
        raise NotImplementedError


class PagedAttnAdapter(CacheAdapter):
    """Full-attention dense/GQA: K/V paged in kernel-block-sized pages."""

    key = "attn"
    param_key = "attn"
    family = "dense/GQA (paged K/V)"
    paged = True
    shareable = True

    def init_pool(self, cfg, geom):
        return attn.paged_cache_init(cfg, geom.num_pages, geom.page_size)

    def copy_page(self, cfg, seg_cache, src, dst):
        return resolve_backend(cfg.decode_backend).paged_copy_page(
            seg_cache, src, dst
        )

    def pool_pspecs(self, cfg, *, tp_axis="model", tp_size=1):
        # stacked pools are (L, num_pages, page, n_kv_heads, d_head): shard
        # the kv-head axis so each device holds (and streams) 1/tp of every
        # page; pages themselves never cross devices.  Query heads arrive
        # pre-partitioned by the column-parallel wq/wk/wv, so only the
        # post-attention row-parallel wo all-reduces.
        if tp_size > 1 and cfg.n_kv_heads % tp_size == 0:
            head = P(None, None, None, tp_axis, None)
            return {"k_pages": head, "v_pages": head}
        return {}

    def install(self, cfg, dst, src, slot, phys_tok, off_tok):
        return _install_paged(dst, src, phys_tok, off_tok,
                              {"k": "k_pages", "v": "v_pages"})

    def src_tokens(self, src):
        return int(src["k"].shape[2])

    def chunk(self, p, cfg, h, positions, cache, ctx, pos_offset):
        return attn.gqa_paged_prefill_chunk(
            p, cfg, h, positions, cache, ctx["table_row"],
            ctx["phys_tok"], ctx["off_tok"], pos_offset,
        )

    def decode(self, p, cfg, h, positions, cache, *, seq_pos, page_table,
               active):
        return attn.gqa_paged_decode(
            p, cfg, h, positions, cache, page_table, seq_pos, active=active
        )


class RingAttnAdapter(CacheAdapter):
    """Sliding-window attention: O(window) ring row per batch slot."""

    key = "attn"
    param_key = "attn"
    family = "SWA (ring)"

    def init_pool(self, cfg, geom):
        return attn.gqa_cache_init(cfg, geom.max_seqs, geom.max_len,
                                   window_only=True)

    def pool_pspecs(self, cfg, *, tp_axis="model", tp_size=1):
        # stacked rings are (L, max_seqs, slots, n_kv_heads, d_head): the
        # head axis shards like the paged pools (ring attention is
        # head-independent); the position labels replicate.
        if tp_size > 1 and cfg.n_kv_heads % tp_size == 0:
            head = P(None, None, None, tp_axis, None)
            return {"k": head, "v": head}
        return {}

    def install(self, cfg, dst, src, slot, phys_tok, off_tok):
        slots_e = dst["k"].shape[2]  # engine ring length: min(window, max_len)
        got = src["k"].shape[2]  # prefill ring length: min(window, S)
        assert got <= slots_e, (got, slots_e)
        # token at absolute position p lives in ring slot p % slots_e; the
        # prefill packing already satisfies this for got == window
        # (== slots_e) and trivially for S < window (identity placement)
        out = {}
        for name, empty in (("k", 0.0), ("v", 0.0), ("pos", -1)):
            L = dst[name].shape[0]
            row_shape = (L, 1) + dst[name].shape[2:]
            row = jnp.full(row_shape, empty, dst[name].dtype)
            row = row.at[:, :, :got].set(src[name].astype(dst[name].dtype))
            out[name] = jax.lax.dynamic_update_slice_in_dim(
                dst[name], row, slot, 1
            )
        return out

    def chunk(self, p, cfg, h, positions, cache, ctx, pos_offset):
        # the first chunk resets the row's position labels to -1 (masked-
        # empty) so a re-used slot cannot leak a previous occupant's window
        row = read_slot_rows(cache, ctx["slot"])
        row["pos"] = jnp.where(ctx["first"], -1, row["pos"])
        out, new_row = attn.gqa_ring_prefill_chunk(
            p, cfg, h, positions, row, pos_offset, window=cfg.window
        )
        return out, write_slot_rows(cache, new_row, ctx["slot"])

    def decode(self, p, cfg, h, positions, cache, *, seq_pos, page_table,
               active):
        return attn.gqa_ring_decode(
            p, cfg, h, positions, cache, seq_pos, window=cfg.window,
            active=active,
        )


class LatentMLAAdapter(CacheAdapter):
    """MLA (DeepSeek-V3): latent ``c_kv`` + shared rotary key paged.

    Pages hold ``kv_lora_rank + qk_rope_dim`` floats per token instead of
    ``2 * n_kv_heads * d_head`` — the families with the most bandwidth to
    save from the paper's block-sized arrangement.  Decode runs the
    absorbed-matmul formulation straight over the gathered latent pages.
    """

    key = "attn"
    param_key = "attn"
    family = "MLA (latent pages)"
    paged = True
    shareable = True

    def init_pool(self, cfg, geom):
        return attn.mla_paged_cache_init(cfg, geom.num_pages, geom.page_size)

    def pool_pspecs(self, cfg, *, tp_axis="model", tp_size=1):
        # MLA latent pools carry NO head axis — the rank-r c_kv and the
        # shared rotary key are consumed by every query head, so the pages
        # replicate (they are tiny: r + dr floats per token vs
        # 2*Hkv*dh).  Head parallelism lives on the activation side: the
        # absorbed q_lat / q_rope are head-sharded by the column-parallel
        # wq projections and each device attends its own heads against the
        # replicated latent pages.
        return {"ckv_pages": P(), "krope_pages": P()}

    def copy_page(self, cfg, seg_cache, src, dst):
        return resolve_backend(cfg.decode_backend).paged_copy_page(
            seg_cache, src, dst
        )

    def install(self, cfg, dst, src, slot, phys_tok, off_tok):
        return _install_paged(dst, src, phys_tok, off_tok,
                              {"ckv": "ckv_pages", "krope": "krope_pages"})

    def src_tokens(self, src):
        return int(src["ckv"].shape[2])

    def chunk(self, p, cfg, h, positions, cache, ctx, pos_offset):
        return attn.mla_paged_prefill_chunk(
            p, cfg, h, positions, cache, ctx["table_row"],
            ctx["phys_tok"], ctx["off_tok"], pos_offset,
        )

    def decode(self, p, cfg, h, positions, cache, *, seq_pos, page_table,
               active):
        return attn.mla_paged_decode(
            p, cfg, h, positions, cache, page_table, seq_pos, active=active
        )


class SSMStateAdapter(CacheAdapter):
    """SSM (mamba2 / hymba branch): O(1) state + conv rows per slot."""

    key = "ssm"
    param_key = "ssm"
    family = "SSM (state rows)"

    def chunk_multiple(self, cfg):
        # chunk boundaries must sit on the SSD chunk grid — the grid the
        # one-shot prefill uses — so every chunk reproduces the exact
        # per-chunk ops of the one-shot path (bit-exactness)
        return cfg.ssm_chunk

    def init_pool(self, cfg, geom):
        return ssmm.ssm_state_init(cfg, geom.max_seqs)

    def install(self, cfg, dst, src, slot, phys_tok, off_tok):
        return write_slot_rows(dst, src, slot, axis=1)

    def chunk(self, p, cfg, h, positions, cache, ctx, pos_offset):
        # on the first chunk the row is zeroed (a fresh request's state; the
        # row may hold garbage from a previous occupant) — zero state /
        # history is bit-identical to prefilling with no carried state
        row = read_slot_rows(cache, ctx["slot"])
        state_in = {
            "state": jnp.where(ctx["first"], 0.0, row["state"]),
            "conv": jnp.where(ctx["first"], 0.0, row["conv"]),
        }
        out, st = ssmm.ssm_forward(p, cfg, h, mode="prefill", state=state_in)
        return out, write_slot_rows(cache, st, ctx["slot"])

    def decode(self, p, cfg, h, positions, cache, *, seq_pos, page_table,
               active):
        out, st = ssmm.ssm_forward(p, cfg, h, mode="decode", state=cache)
        if active is not None:
            st = jax.tree.map(
                lambda new, old: jnp.where(
                    active.reshape((-1,) + (1,) * (new.ndim - 1)),
                    new.astype(old.dtype), old,
                ), st, cache,
            )
        return out, st


class CrossAttnAdapter(CacheAdapter):
    """Encoder-decoder cross-attention: immutable encoder-side K/V rows.

    The encoder runs ONCE per request at admission; its projected K/V are
    installed into the slot's rows and never written again — chunked
    decoder prefill and decode both read the same rows, so preemption-with-
    recompute only re-runs the encoder, never corrupts it mid-stream.
    """

    key = "cross"
    param_key = "cross"
    family = "enc-dec (cross rows + paged self-attn)"
    installs_at_admission = True
    side_inputs = True  # cache content depends on the request's audio

    def init_pool(self, cfg, geom):
        dh = cfg.d_head
        return {
            "k": jnp.zeros(
                (geom.max_seqs, cfg.encoder_seq, cfg.n_kv_heads, dh), cfg.dtype
            ),
            "v": jnp.zeros(
                (geom.max_seqs, cfg.encoder_seq, cfg.n_kv_heads, dh), cfg.dtype
            ),
        }

    def pool_pspecs(self, cfg, *, tp_axis="model", tp_size=1):
        # stacked cross rows are (L, max_seqs, encoder_seq, n_kv_heads,
        # d_head): immutable per request, head-sharded like the paged pools
        # so cross-attention reads stay local to each device's heads.
        if tp_size > 1 and cfg.n_kv_heads % tp_size == 0:
            head = P(None, None, None, tp_axis, None)
            return {"k": head, "v": head}
        return {}

    def install(self, cfg, dst, src, slot, phys_tok, off_tok):
        return write_slot_rows(dst, src, slot, axis=1)

    def admission_src(self, cfg, params, batch: Dict) -> Dict:
        """Encoder-side K/V for one request, as a partial install source
        (the jitted call is memoized per config).  The stacked per-layer
        rows are split along the segment boundaries, so a multi-segment
        decoder gets every segment's share — no seg0 special case."""
        kv = _cross_src_fn(cfg)(params, batch["audio_embeds"])
        src, off = {}, 0
        for si, (kind, n) in enumerate(layer_segments(cfg)):
            if self in adapters_for(cfg, kind):
                src[f"seg{si}"] = {"cross": jax.tree.map(
                    lambda a: a[off:off + n], kv
                )}
            off += n
        return src

    def chunk(self, p, cfg, h, positions, cache, ctx, pos_offset):
        rows = read_slot_rows(cache, ctx["slot"])
        return attn.cross_attention(p, cfg, h, rows["k"], rows["v"]), cache

    def decode(self, p, cfg, h, positions, cache, *, seq_pos, page_table,
               active):
        # read-only: inactive slots produce garbage that is discarded, and
        # there is no write to mask
        return attn.cross_attention(p, cfg, h, cache["k"], cache["v"]), cache


@functools.lru_cache(maxsize=None)
def _cross_src_fn(cfg: ModelConfig):
    from repro.models import model as M

    return jax.jit(functools.partial(M.encdec_cross_kv, cfg))


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

PAGED_GQA = PagedAttnAdapter()
RING_SWA = RingAttnAdapter()
MLA_LATENT = LatentMLAAdapter()
SSM_STATE = SSMStateAdapter()
CROSS_ENC = CrossAttnAdapter()

_ATTN_ADAPTERS = {"full": PAGED_GQA, "swa": RING_SWA, "mla": MLA_LATENT}


def adapters_for(cfg: ModelConfig, kind: str) -> List[CacheAdapter]:
    """Adapters serving one segment kind, in mixer order (attention first —
    the hybrid fusion averages outputs in this order)."""
    ads: List[CacheAdapter] = []
    if kind in ("dense", "moe", "hybrid"):
        ads.append(_ATTN_ADAPTERS[cfg.attn_type])
        if cfg.n_encoder_layers:
            ads.append(CROSS_ENC)
    if kind in ("ssm", "hybrid"):
        ads.append(SSM_STATE)
    return ads


def all_adapters(cfg: ModelConfig) -> List[CacheAdapter]:
    """Every adapter the config's segments use (deduplicated, in order)."""
    seen: List[CacheAdapter] = []
    for kind, _n in layer_segments(cfg):
        for ad in adapters_for(cfg, kind):
            if ad not in seen:
                seen.append(ad)
    return seen


def admission_adapters(cfg: ModelConfig) -> List[CacheAdapter]:
    """Adapters that install request-level context once at admission,
    outside the token-chunk loop (e.g. enc-dec encoder K/V)."""
    return [
        ad for ad in all_adapters(cfg)
        if getattr(ad, "installs_at_admission", False)
    ]


def prefix_shareable(cfg: ModelConfig) -> bool:
    """Whether this config's physical pages may be ALIASED across requests
    with a matching token prefix (memory dedup + COW on divergence).

    Requires at least one shareable paged adapter (something to alias) and
    no side-input family in the stack: enc-dec hidden states depend on the
    request's audio, so token-keyed aliasing is unsound for every layer of
    that stack.  Non-shareable slot-local adapters (rings, SSM states) do
    NOT block aliasing of their paged co-residents — they only block
    compute skipping (see :func:`prefix_compute_skippable`).
    """
    ads = all_adapters(cfg)
    return (any(ad.shareable for ad in ads)
            and not any(ad.side_inputs for ad in ads))


def prefix_compute_skippable(cfg: ModelConfig) -> bool:
    """Whether a cached prefix lets admission SKIP the prefix's prefill
    chunks entirely (start chunking at the first uncached page boundary).

    Stricter than :func:`prefix_shareable`: every adapter must be
    shareable (a ring/SSM row is a slot-local summary of the whole
    sequence, so those families must still run every prompt token even
    when the attention pages are aliased), and MoE segments must be absent
    (capacity dispatch groups tokens per forward call, so a suffix-only
    chunk would regroup the dispatch — the documented multi-chunk MoE
    caveat; MoE stacks alias pages for the memory win and recompute).
    """
    if not prefix_shareable(cfg):
        return False
    if any(kind == "moe" for kind, _n in layer_segments(cfg)):
        return False
    return all(ad.shareable for ad in all_adapters(cfg))


def prefill_chunk_multiple(cfg: ModelConfig) -> int:
    """Grid every prefill chunk boundary must sit on (lcm over adapters)."""
    m = 1
    for ad in all_adapters(cfg):
        m = math.lcm(m, ad.chunk_multiple(cfg))
    return m


def supported_families() -> Tuple[str, ...]:
    """Family names the adapter registry serves (the engine error text and
    the launch driver report exactly this list)."""
    return (
        PAGED_GQA.family,
        RING_SWA.family,
        MLA_LATENT.family,
        SSM_STATE.family,
        CROSS_ENC.family,
    )


def unsupported_reason(cfg: ModelConfig) -> Optional[str]:
    """Why the continuous-batching engine cannot serve this config (None =
    it can).  The only hole left: the vision frontend's M-RoPE prefix."""
    if cfg.frontend == "vision" or cfg.mrope_sections:
        return (
            "the vision frontend (M-RoPE position streams + image prefix) "
            "has no cache adapter yet"
        )
    return None


def unsupported_message(cfg: ModelConfig, hint: str = "") -> Optional[str]:
    """The ONE unsupported-family error text (None = config is served):
    the reason plus exactly the families the registry reports.  Every
    refusing layer (kvcache, launch driver) formats through here so the
    copies cannot drift."""
    reason = unsupported_reason(cfg)
    if reason is None:
        return None
    msg = (f"{cfg.name}: {reason}; the paged engine serves: "
           + ", ".join(supported_families()))
    return msg + (f" — {hint}" if hint else "")

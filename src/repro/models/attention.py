"""Attention modules: GQA (full / sliding-window, RoPE / M-RoPE) and MLA.

Functional style: ``init`` returns a params dict; ``forward`` handles the
three execution modes:

* ``train``    — full-sequence, no cache;
* ``prefill``  — full-sequence, returns a populated cache;
* ``decode``   — one token against the cache (ring buffer for SWA).

MLA (DeepSeek-V3) caches the *latent* c_kv + the shared rotary key — the
point of MLA — and uses the absorbed-matmul formulation at decode time.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.backend import resolve_backend
from repro.distributed.axes import constrain
from repro.models.common import (
    apply_mrope,
    apply_rope,
    chunked_attention,
    decode_attention,
    dense,
    dense_init,
)


# --------------------------------------------------------------------------
# GQA
# --------------------------------------------------------------------------

def gqa_init(key, cfg: ModelConfig) -> Dict:
    d, H, Hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, H * dh, cfg.dtype),
        "wk": dense_init(ks[1], d, Hkv * dh, cfg.dtype),
        "wv": dense_init(ks[2], d, Hkv * dh, cfg.dtype),
        "wo": dense_init(ks[3], H * dh, d, cfg.dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * dh,), cfg.dtype)
        p["bk"] = jnp.zeros((Hkv * dh,), cfg.dtype)
        p["bv"] = jnp.zeros((Hkv * dh,), cfg.dtype)
    return p


def gqa_cache_init(cfg: ModelConfig, batch: int, max_len: int, window_only: bool):
    """Cache for one layer.  SWA keeps only ``window`` slots (ring buffer)."""
    slots = min(cfg.window, max_len) if window_only else max_len
    dh = cfg.d_head
    return {
        "k": jnp.zeros((batch, slots, cfg.n_kv_heads, dh), cfg.dtype),
        "v": jnp.zeros((batch, slots, cfg.n_kv_heads, dh), cfg.dtype),
        "pos": jnp.full((batch, slots), -1, jnp.int32),
    }


def _project_qkv(p, cfg: ModelConfig, x, positions):
    B, S, _ = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = dense(cfg, x, p["wq"])
    k = dense(cfg, x, p["wk"])
    v = dense(cfg, x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = constrain(q, ("dp", None, "tp"))
    k = constrain(k, ("dp", None, "tp"))
    v = constrain(v, ("dp", None, "tp"))
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, S, Hkv, dh)
    v = v.reshape(B, S, Hkv, dh)
    if cfg.use_rope:
        if cfg.mrope_sections:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_forward(
    p: Dict,
    cfg: ModelConfig,
    x: jnp.ndarray,  # (B, S, d)
    positions,  # (B, S) or (3, B, S) for M-RoPE
    *,
    mode: str = "train",
    cache: Optional[Dict] = None,
    pos_offset=0,  # scalar: absolute position of x[:, 0] (decode/prefill)
    causal: bool = True,
    window: Optional[int] = None,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, S, _ = x.shape
    window = window if window is not None else (
        cfg.window if cfg.attn_type == "swa" else None  # repro: noqa RPR004 -- default-arg resolution, not family dispatch
    )
    q, k, v = _project_qkv(p, cfg, x, positions)

    if mode == "decode":
        assert cache is not None and S == 1
        slots = cache["k"].shape[1]
        slot = jnp.mod(pos_offset, slots)
        k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        pos_new = jnp.full((B, 1), pos_offset, jnp.int32)
        pos_cache = jax.lax.dynamic_update_slice(cache["pos"], pos_new, (0, slot))
        out = decode_attention(
            q, k_cache, v_cache, pos_cache, pos_offset, window=window
        )
        new_cache = {"k": k_cache, "v": v_cache, "pos": pos_cache}
    else:
        out = chunked_attention(
            q, k, v, causal=causal, q_offset=pos_offset,
            window=window, q_chunk=cfg.q_chunk,
        )
        new_cache = None
        if mode == "prefill":
            # populate the cache (SWA: keep the trailing ``window`` tokens)
            slots = min(cfg.window, S) if window is not None else S
            ks, vs = k[:, -slots:], v[:, -slots:]
            pos = jnp.broadcast_to(
                jnp.arange(S - slots, S, dtype=jnp.int32)[None], (B, slots)
            )
            if window is not None and slots == cfg.window:
                # ring-buffer order: token at absolute position p sits in slot
                # p % window, so later decode steps index consistently.
                slot_of = jnp.mod(jnp.arange(S - slots, S), slots)
                inv = jnp.argsort(slot_of)
                ks, vs, pos = ks[:, inv], vs[:, inv], pos[:, inv]
            new_cache = {"k": ks, "v": vs, "pos": pos}
    out = out.reshape(B, S, cfg.n_heads * cfg.d_head)
    return dense(cfg, out, p["wo"]), new_cache


# --------------------------------------------------------------------------
# Paged GQA decode (block-paged KV cache, page size = accelerator block)
# --------------------------------------------------------------------------

def paged_cache_init(cfg: ModelConfig, num_pages: int, page_size: int) -> Dict:
    """One layer's share of the physical page pool.

    Pages are the accelerator-block-sized unit of cache memory (the paper's
    arrangement quantum applied to the KV cache): page ``i`` of this layer
    holds ``page_size`` contiguous token slots.  Physical page ids are shared
    across layers — a request's page table indexes every layer's pool with
    the same ids.  Page 0 is reserved as the null page (write target for
    inactive slots, gather target for unmapped table entries).
    """
    dh = cfg.d_head
    return {
        "k_pages": jnp.zeros((num_pages, page_size, cfg.n_kv_heads, dh), cfg.dtype),
        "v_pages": jnp.zeros((num_pages, page_size, cfg.n_kv_heads, dh), cfg.dtype),
    }


def paged_gather_attend(q, k_pages, v_pages, page_table, seq_pos):
    """The jnp gather->attend oracle read (the ``"reference"`` backend op).

    Gathers each slot's logical pages back into a dense (B, max_pages*page,
    Hkv, dh) HBM buffer and runs the same masked one-token attention as the
    linear cache — keys beyond ``seq_pos`` (tail of a partial page, unmapped
    null-page entries, stale pages of retired requests) sit at positions
    above it and mask exactly like empty slots.
    """
    B = q.shape[0]
    page, hkv, dh = k_pages.shape[1], k_pages.shape[2], k_pages.shape[3]
    maxp = page_table.shape[1]
    kg = k_pages[page_table].reshape(B, maxp * page, hkv, dh)
    vg = v_pages[page_table].reshape(B, maxp * page, hkv, dh)
    # gathered keys sit at their absolute positions by construction
    k_positions = jnp.broadcast_to(
        jnp.arange(maxp * page, dtype=jnp.int32)[None], (B, maxp * page)
    )
    return decode_attention(q, kg, vg, k_positions, seq_pos, window=None)


def gqa_paged_decode(
    p: Dict,
    cfg: ModelConfig,
    x: jnp.ndarray,  # (B, 1, d) — one token per slot
    positions: jnp.ndarray,  # (B, 1) per-slot absolute positions (RoPE)
    cache: Dict,  # {"k_pages", "v_pages"} (num_pages, page, Hkv, dh)
    page_table: jnp.ndarray,  # (B, max_pages) physical page per logical page
    seq_pos: jnp.ndarray,  # (B,) absolute position of the new token
    active: Optional[jnp.ndarray] = None,  # (B,) slots actually decoding
) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode against the block-paged cache.

    Write: the new K/V lands in page ``page_table[b, pos // page]`` at offset
    ``pos % page``.  Read: through ``cfg.decode_backend`` — the reference
    backend gathers each slot's logical pages back into order and runs the
    linear cache's masked attention (:func:`paged_gather_attend`); the
    pallas backend streams the page-table row through the fused kernel
    without materializing the gathered history.

    ``active`` marks slots whose write should land: inactive slots (idle,
    or mid-way through a chunked prefill — whose page table rows are live!)
    are routed to the reserved null page so the lockstep batch step cannot
    corrupt state it does not own.
    """
    B, S, _ = x.shape
    assert S == 1
    q, k, v = _project_qkv(p, cfg, x, positions)
    page = cache["k_pages"].shape[1]
    logical = seq_pos // page  # (B,) logical page of the new token
    phys = jnp.take_along_axis(page_table, logical[:, None], axis=1)[:, 0]
    if active is not None:
        phys = jnp.where(active, phys, 0)  # null page absorbs idle writes
    off = seq_pos % page
    # scatter the new token (inactive slots carry page_table rows of 0 and
    # seq_pos 0, so their writes land in the reserved null page)
    k_pages = cache["k_pages"].at[phys, off].set(k[:, 0])
    v_pages = cache["v_pages"].at[phys, off].set(v[:, 0])
    be = resolve_backend(cfg.decode_backend)
    out = be.paged_attention_decode(q, k_pages, v_pages, page_table, seq_pos)
    out = out.reshape(B, 1, cfg.n_heads * cfg.d_head)
    return dense(cfg, out, p["wo"]), {"k_pages": k_pages, "v_pages": v_pages}


def paged_copy_page(cache: Dict, src, dst) -> Dict:
    """Copy one physical page (``src`` -> ``dst``) in every page pool.

    The copy-on-write step for shared-prefix serving: when a slot must write
    into a page whose refcount is > 1 (aliased by other requests or pinned
    by the prefix index), the host allocates a fresh page, this copy runs
    inside a donating jit, and the slot's page-table entry is swapped to the
    private copy.  Page ids are traced scalars, so every COW event shares
    one compiled shape.  Works on any pool whose leaves are
    ``(L, num_pages, page, ...)`` — dense/GQA K/V pages and MLA latent pages
    alike (the page axis is axis 1 after the layer stack).

    This dense dynamic-slice copy is the ``"reference"`` backend's op; the
    adapters dispatch through ``cfg.decode_backend``, and the pallas
    backend replaces it with the scalar-prefetched single-page copy kernel
    (:func:`repro.kernels.paged_attention.paged_copy`) — bit-exact either
    way.
    """
    out = {}
    for name, pool in cache.items():
        row = jax.lax.dynamic_slice_in_dim(pool, src, 1, axis=1)
        out[name] = jax.lax.dynamic_update_slice_in_dim(pool, row, dst, axis=1)
    return out


def gqa_paged_prefill_chunk(
    p: Dict,
    cfg: ModelConfig,
    x: jnp.ndarray,  # (1, C, d) — one prompt chunk for one slot
    positions: jnp.ndarray,  # (1, C) absolute positions q_off + [0, C)
    cache: Dict,  # {"k_pages", "v_pages"} (num_pages, page, Hkv, dh)
    table_row: jnp.ndarray,  # (max_pages,) this slot's page table row
    phys_tok: jnp.ndarray,  # (C,) physical page per chunk token
    off_tok: jnp.ndarray,  # (C,) in-page offset per chunk token
    q_off,  # scalar absolute position of x[:, 0]
) -> Tuple[jnp.ndarray, Dict]:
    """One prompt chunk against the block-paged cache (prefix-conditioned).

    Write first: the chunk's K/V scatters straight into its physical pages
    (per-token ``(phys, off)`` targets; tokens past the slot's allocation
    are routed to the null page by the host).  Then gather the slot's whole
    page table back into logical order — the prefix written by earlier
    chunks AND this chunk's own keys — and run the same causal masked
    attention as full prefill.  Gathered keys sit at their absolute
    positions, so every unmasked key matches the one-shot prefill's key
    sequence in ascending-position order (bit-exactness) and pages beyond
    the current position mask out exactly like empty cache slots.
    """
    B, C, _ = x.shape
    assert B == 1
    q, k, v = _project_qkv(p, cfg, x, positions)
    k_pages = cache["k_pages"].at[phys_tok, off_tok].set(k[0])
    v_pages = cache["v_pages"].at[phys_tok, off_tok].set(v[0])
    page = k_pages.shape[1]
    maxp = table_row.shape[0]
    kg = k_pages[table_row].reshape(1, maxp * page, cfg.n_kv_heads, cfg.d_head)
    vg = v_pages[table_row].reshape(1, maxp * page, cfg.n_kv_heads, cfg.d_head)
    kpos = jnp.arange(maxp * page, dtype=jnp.int32)[None]
    out = chunked_attention(
        q, kg, vg, causal=True, q_offset=q_off, k_positions=kpos,
        q_chunk=cfg.q_chunk,
    )
    out = out.reshape(B, C, cfg.n_heads * cfg.d_head)
    return dense(cfg, out, p["wo"]), {"k_pages": k_pages, "v_pages": v_pages}


def gqa_ring_prefill_chunk(
    p: Dict,
    cfg: ModelConfig,
    x: jnp.ndarray,  # (1, C, d)
    positions: jnp.ndarray,  # (1, C)
    cache_row: Dict,  # {"k", "v", "pos"} — (1, slots, ...) this slot's ring
    q_off,  # scalar absolute position of x[:, 0]
    *,
    window: int,
) -> Tuple[jnp.ndarray, Dict]:
    """One prompt chunk against the O(window) ring buffer (SWA).

    The prefix is gathered from the ring in **ascending position order**
    (ring slot of position p is p % slots, so the gather is a rotation);
    empty or reset entries carry position label -1 and mask out.  Attention
    then runs over [prefix ; chunk] with the same causal + window masking
    as full prefill — ascending-position key order keeps the surviving
    softmax terms in the one-shot prefill's summation order (bit-exactness).
    The chunk's trailing min(C, slots) tokens are then written into the ring
    at their p % slots homes, the layout every later chunk and decode step
    expects.
    """
    B, C, _ = x.shape
    assert B == 1
    q, k, v = _project_qkv(p, cfg, x, positions)
    slots = cache_row["k"].shape[1]
    # prefix positions q_off-slots .. q_off-1 in ascending order
    pref_pos = q_off - slots + jnp.arange(slots, dtype=jnp.int32)
    idx = jnp.mod(pref_pos, slots)
    keys = jnp.concatenate([cache_row["k"][:, idx], k], axis=1)
    vals = jnp.concatenate([cache_row["v"][:, idx], v], axis=1)
    kpos = jnp.concatenate([cache_row["pos"][:, idx], positions], axis=1)
    out = chunked_attention(
        q, keys, vals, causal=True, q_offset=q_off, k_positions=kpos,
        window=window, q_chunk=cfg.q_chunk,
    )
    # persist the chunk's trailing tokens (older ones fall off the ring)
    w = min(C, slots)
    wpos = positions[0, C - w:]  # (w,)
    widx = jnp.mod(wpos, slots)
    new_row = {
        "k": cache_row["k"].at[:, widx].set(k[:, C - w:]),
        "v": cache_row["v"].at[:, widx].set(v[:, C - w:]),
        "pos": cache_row["pos"].at[:, widx].set(wpos[None]),
    }
    out = out.reshape(B, C, cfg.n_heads * cfg.d_head)
    return dense(cfg, out, p["wo"]), new_row


def cross_attention(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                    k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Non-causal attention over a fixed encoder-side K/V (enc-dec cross).

    The ONE implementation both the static decoder layer and the engine's
    cross adapter call — q/softmax/output math cannot drift between them
    (the bit-exactness guarantee leans on this).
    """
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, cfg.d_head)
    out = chunked_attention(q, k, v, causal=False, q_chunk=cfg.q_chunk)
    return out.reshape(B, S, -1) @ p["wo"]


def gqa_ring_decode(
    p: Dict,
    cfg: ModelConfig,
    x: jnp.ndarray,  # (B, 1, d)
    positions: jnp.ndarray,  # (B, 1)
    cache: Dict,  # {"k", "v", "pos"} — (B, slots, ...) ring buffer
    seq_pos: jnp.ndarray,  # (B,) absolute position of the new token
    *,
    window: Optional[int] = None,
    active: Optional[jnp.ndarray] = None,  # (B,) slots actually decoding
) -> Tuple[jnp.ndarray, Dict]:
    """Per-slot-position decode against the O(window) ring buffer (SWA).

    Same layout as the static-wave ring (token at absolute position p sits in
    slot p % slots) but each batch slot advances independently, which is what
    continuous batching needs.  Inactive slots (idle, or mid-way through a
    chunked prefill whose ring rows are being built incrementally) write to
    ring index ``slots`` — out of bounds, so the scatter drops it.
    """
    B, S, _ = x.shape
    assert S == 1
    q, k, v = _project_qkv(p, cfg, x, positions)
    slots = cache["k"].shape[1]
    slot = seq_pos % slots  # (B,)
    if active is not None:
        slot = jnp.where(active, slot, slots)  # OOB scatter index: dropped
    rows = jnp.arange(B)
    k_cache = cache["k"].at[rows, slot].set(k[:, 0])
    v_cache = cache["v"].at[rows, slot].set(v[:, 0])
    pos_cache = cache["pos"].at[rows, slot].set(seq_pos)
    out = decode_attention(q, k_cache, v_cache, pos_cache, seq_pos, window=window)
    out = out.reshape(B, 1, cfg.n_heads * cfg.d_head)
    new_cache = {"k": k_cache, "v": v_cache, "pos": pos_cache}
    return dense(cfg, out, p["wo"]), new_cache


# --------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# --------------------------------------------------------------------------
# Paged variants live below mla_forward: the engine pages the *latent*
# c_kv + shared rotary key (kv_lora_rank + qk_rope_dim floats per token
# instead of 2 * n_kv_heads * d_head) and decodes with the absorbed-matmul
# formulation straight over the gathered latent pages.

def mla_init(key, cfg: ModelConfig) -> Dict:
    d, H = cfg.d_model, cfg.n_heads
    r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], d, r_q, cfg.dtype),
        "q_norm": jnp.ones((r_q,), cfg.dtype),
        "wq_b": dense_init(ks[1], r_q, H * (dn + dr), cfg.dtype),
        "wkv_a": dense_init(ks[2], d, r_kv + dr, cfg.dtype),
        "kv_norm": jnp.ones((r_kv,), cfg.dtype),
        "wkv_b": dense_init(ks[3], r_kv, H * (dn + dv), cfg.dtype),
        "wo": dense_init(ks[4], H * dv, d, cfg.dtype),
    }


def mla_cache_init(cfg: ModelConfig, batch: int, max_len: int):
    """MLA caches the latent (r_kv) + shared rotary key (dr) — tiny vs GQA."""
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), cfg.dtype),
        "krope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), cfg.dtype),
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
    }


def _rms(x, w, eps=1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def _mla_qkv_latent(p, cfg: ModelConfig, x, positions):
    """Common projections: per-head q (nope+rope), latent ckv, shared k_rope."""
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = _rms(x @ p["wq_a"], p["q_norm"]) @ p["wq_b"]
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    kv = x @ p["wkv_a"]  # (B, S, r_kv + dr)
    ckv = _rms(kv[..., : cfg.kv_lora_rank], p["kv_norm"])
    k_rope = apply_rope(
        kv[..., cfg.kv_lora_rank:][:, :, None, :], positions, cfg.rope_theta
    )[:, :, 0]  # (B, S, dr) — shared across heads
    return q_nope, q_rope, ckv, k_rope


# The two MLA attention formulations, each implemented ONCE: the linear-
# cache decode and the paged decode both call _mla_absorbed_attend, the
# one-shot prefill and the paged prefill chunk both call
# _mla_expanded_attend — the engine's bit-exactness guarantee against the
# static Server leans on the math being impossible to drift apart.

def mla_latent_attend(q_lat, q_rope, ckv_c, kr_c, valid, *, scale):
    """Latent-space MLA attention (the absorbed formulation's core).

    ``q_lat``: (B, S, H, r) — q_nope already absorbed through ``W_kv_b``;
    ``valid``: (B, K) key mask.  Returns the latent-space output ``o_lat``
    (B, S, H, r) — the caller applies the value expansion.  This is the
    ``"reference"`` backend's MLA decode read (over gathered latents); the
    pallas kernel reproduces exactly this math page-by-page.
    """
    s = jnp.einsum("bshr,bkr->bhsk", q_lat, ckv_c,
                   preferred_element_type=jnp.float32)
    s += jnp.einsum("bshd,bkd->bhsk", q_rope, kr_c,
                    preferred_element_type=jnp.float32)
    s *= scale
    s = jnp.where(valid[:, None, None, :], s, jnp.finfo(jnp.float32).min)
    att = jax.nn.softmax(s, -1).astype(ckv_c.dtype)  # (B, H, S, K)
    return jnp.einsum("bhsk,bkr->bshr", att, ckv_c)


def mla_paged_gather_attend(q_lat, q_rope, ckv_pages, krope_pages,
                            page_table, seq_pos, *, scale):
    """The jnp gather->attend oracle over latent pages (reference op).

    Gathers each slot's latent pages into logical order and scores with
    :func:`mla_latent_attend`; gathered entries sit at their absolute
    positions, so masking by ``k_pos <= seq_pos`` reproduces the linear
    cache's valid set exactly.
    """
    B = q_lat.shape[0]
    page, r_kv = ckv_pages.shape[1], ckv_pages.shape[2]
    maxp = page_table.shape[1]
    ckv_g = ckv_pages[page_table].reshape(B, maxp * page, r_kv)
    kr_g = krope_pages[page_table].reshape(B, maxp * page, -1)
    k_positions = jnp.arange(maxp * page, dtype=jnp.int32)
    valid = k_positions[None] <= seq_pos[:, None]  # (B, K)
    return mla_latent_attend(q_lat, q_rope, ckv_g, kr_g, valid, scale=scale)


def _mla_absorbed_attend(cfg: ModelConfig, wkv_b, q_nope, q_rope,
                         ckv_c, kr_c, valid):
    """Absorbed-matmul MLA attention over a latent cache.

    score = q_nope . (W_kv_b,k^T c) + q_rope . k_rope
          = (q_nope W_k^T) . c + q_rope . k_rope
    ``valid``: (B, K) key mask.  Returns (B, S, H, v_head_dim).
    """
    dn = cfg.qk_nope_dim
    scale = (dn + cfg.qk_rope_dim) ** -0.5
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, wkv_b[..., :dn])
    o_lat = mla_latent_attend(q_lat, q_rope, ckv_c, kr_c, valid, scale=scale)
    return jnp.einsum("bshr,rhd->bshd", o_lat, wkv_b[..., dn:])  # value expand


def _mla_expanded_attend(cfg: ModelConfig, wkv_b, q_nope, q_rope,
                         ckv, k_rope, *, pos_offset, k_positions=None):
    """Expanded-formulation MLA attention (train / prefill / prefill chunk).

    Each key position's kv expansion depends only on its own latent, so the
    same call serves contiguous latents and page-gathered ones (with
    ``k_positions`` labelling the gathered order).
    """
    H = cfg.n_heads
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    scale = (dn + dr) ** -0.5
    B, K = ckv.shape[:2]
    kv = jnp.einsum("bsr,rhd->bshd", ckv, wkv_b)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, K, H, dr))], -1
    )
    q = jnp.concatenate([q_nope, q_rope], -1)
    return chunked_attention(
        q, k, v, causal=True, q_offset=pos_offset, k_positions=k_positions,
        q_chunk=cfg.q_chunk, scale=scale,
    )


def mla_forward(
    p: Dict,
    cfg: ModelConfig,
    x: jnp.ndarray,
    positions,
    *,
    mode: str = "train",
    cache: Optional[Dict] = None,
    pos_offset=0,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dv = cfg.qk_nope_dim, cfg.v_head_dim
    r_kv = cfg.kv_lora_rank
    q_nope, q_rope, ckv, k_rope = _mla_qkv_latent(p, cfg, x, positions)
    wkv_b = p["wkv_b"].reshape(r_kv, H, dn + dv)

    if mode == "decode":
        assert cache is not None and S == 1
        ckv_c = jax.lax.dynamic_update_slice(cache["ckv"], ckv, (0, pos_offset, 0))
        kr_c = jax.lax.dynamic_update_slice(cache["krope"], k_rope, (0, pos_offset, 0))
        pos_new = jnp.full((B, 1), pos_offset, jnp.int32)
        pos_c = jax.lax.dynamic_update_slice(cache["pos"], pos_new, (0, pos_offset))
        valid = (pos_c >= 0) & (pos_c <= pos_offset)
        out = _mla_absorbed_attend(cfg, wkv_b, q_nope, q_rope, ckv_c, kr_c,
                                   valid)
        new_cache = {"ckv": ckv_c, "krope": kr_c, "pos": pos_c}
    else:
        out = _mla_expanded_attend(cfg, wkv_b, q_nope, q_rope, ckv, k_rope,
                                   pos_offset=pos_offset)
        new_cache = None
        if mode == "prefill":
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
            new_cache = {"ckv": ckv, "krope": k_rope, "pos": pos}
    out = out.reshape(B, S, H * dv)
    return out @ p["wo"], new_cache


# --------------------------------------------------------------------------
# Paged MLA (latent pages — the continuous-batching engine's MLA cache)
# --------------------------------------------------------------------------

def mla_paged_cache_init(cfg: ModelConfig, num_pages: int, page_size: int) -> Dict:
    """One layer's share of the latent page pool.

    A page holds ``page_size`` token slots of the MLA *latent* cache — the
    rank-``kv_lora_rank`` c_kv plus the shared ``qk_rope_dim`` rotary key —
    which is all the absorbed-matmul decode ever reads.  Same page-id space
    and null-page discipline as the dense K/V pool.
    """
    return {
        "ckv_pages": jnp.zeros(
            (num_pages, page_size, cfg.kv_lora_rank), cfg.dtype
        ),
        "krope_pages": jnp.zeros(
            (num_pages, page_size, cfg.qk_rope_dim), cfg.dtype
        ),
    }


def mla_paged_decode(
    p: Dict,
    cfg: ModelConfig,
    x: jnp.ndarray,  # (B, 1, d) — one token per slot
    positions: jnp.ndarray,  # (B, 1) per-slot absolute positions (RoPE)
    cache: Dict,  # {"ckv_pages", "krope_pages"}
    page_table: jnp.ndarray,  # (B, max_pages) physical page per logical page
    seq_pos: jnp.ndarray,  # (B,) absolute position of the new token
    active: Optional[jnp.ndarray] = None,  # (B,) slots actually decoding
) -> Tuple[jnp.ndarray, Dict]:
    """Absorbed-matmul decode against the latent page pool.

    Write: the new token's (c_kv, k_rope) lands in its slot's page.  Read:
    through ``cfg.decode_backend``, always in the absorbed formulation —
    q_nope is folded into the latent space through ``W_kv_b`` so attention
    runs over rank-r latents, never materializing per-head K/V.  The
    reference backend gathers the latent pages into logical order
    (:func:`mla_paged_gather_attend`); the pallas backend streams them
    page-by-page through the fused kernel.  Either way entries sit at
    their absolute positions, so masking by ``k_pos <= seq_pos``
    reproduces the linear cache's valid set exactly (stale pages /
    partial-page tails mask out like empty slots).
    """
    B, S, _ = x.shape
    assert S == 1
    H = cfg.n_heads
    dn, dv, r_kv = cfg.qk_nope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    q_nope, q_rope, ckv, k_rope = _mla_qkv_latent(p, cfg, x, positions)
    wkv_b = p["wkv_b"].reshape(r_kv, H, dn + dv)

    page = cache["ckv_pages"].shape[1]
    logical = seq_pos // page
    phys = jnp.take_along_axis(page_table, logical[:, None], axis=1)[:, 0]
    if active is not None:
        phys = jnp.where(active, phys, 0)  # null page absorbs idle writes
    off = seq_pos % page
    ckv_pages = cache["ckv_pages"].at[phys, off].set(ckv[:, 0])
    krope_pages = cache["krope_pages"].at[phys, off].set(k_rope[:, 0])

    scale = (dn + cfg.qk_rope_dim) ** -0.5
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, wkv_b[..., :dn])
    be = resolve_backend(cfg.decode_backend)
    o_lat = be.mla_paged_attention_decode(
        q_lat, q_rope, ckv_pages, krope_pages, page_table, seq_pos,
        scale=scale,
    )
    out = jnp.einsum("bshr,rhd->bshd", o_lat, wkv_b[..., dn:])  # value expand
    out = out.reshape(B, 1, H * dv)
    return out @ p["wo"], {"ckv_pages": ckv_pages, "krope_pages": krope_pages}


def mla_paged_prefill_chunk(
    p: Dict,
    cfg: ModelConfig,
    x: jnp.ndarray,  # (1, C, d) — one prompt chunk for one slot
    positions: jnp.ndarray,  # (1, C) absolute positions q_off + [0, C)
    cache: Dict,  # {"ckv_pages", "krope_pages"}
    table_row: jnp.ndarray,  # (max_pages,) this slot's page table row
    phys_tok: jnp.ndarray,  # (C,) physical page per chunk token
    off_tok: jnp.ndarray,  # (C,) in-page offset per chunk token
    q_off,  # scalar absolute position of x[:, 0]
) -> Tuple[jnp.ndarray, Dict]:
    """One prompt chunk against the latent page pool (prefix-conditioned).

    Write first (per-token latent scatter), then gather the slot's whole
    table row and run the *expanded* formulation over the gathered latent —
    the same per-position kv expansion and causal masked attention the
    one-shot prefill uses, so every unmasked key matches the one-shot key
    sequence in ascending-position order (bit-exactness).  The absorbed
    formulation is reserved for decode, where it is the win.
    """
    B, C, _ = x.shape
    assert B == 1
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r_kv = cfg.kv_lora_rank
    q_nope, q_rope, ckv, k_rope = _mla_qkv_latent(p, cfg, x, positions)
    wkv_b = p["wkv_b"].reshape(r_kv, H, dn + dv)

    ckv_pages = cache["ckv_pages"].at[phys_tok, off_tok].set(ckv[0])
    krope_pages = cache["krope_pages"].at[phys_tok, off_tok].set(k_rope[0])
    page = ckv_pages.shape[1]
    maxp = table_row.shape[0]
    K = maxp * page
    ckv_g = ckv_pages[table_row].reshape(1, K, r_kv)
    kr_g = krope_pages[table_row].reshape(1, K, dr)
    kpos = jnp.arange(K, dtype=jnp.int32)[None]
    out = _mla_expanded_attend(cfg, wkv_b, q_nope, q_rope, ckv_g, kr_g,
                               pos_offset=q_off, k_positions=kpos)
    out = out.reshape(B, C, H * dv)
    return out @ p["wo"], {"ckv_pages": ckv_pages, "krope_pages": krope_pages}

"""Shared building blocks: norms, RoPE / M-RoPE, chunked attention math."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def norm_apply(cfg: ModelConfig, w, x, b=None, eps: float = 1e-5):
    """RMSNorm or LayerNorm, computed in fp32 (standard practice)."""
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        y = y * w.astype(jnp.float32)
    else:
        mean = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean((xf - mean) ** 2, -1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
        if b is not None:
            y = y + b.astype(jnp.float32)
    return y.astype(x.dtype)


def norm_init(cfg: ModelConfig, d: int):
    if cfg.norm == "rmsnorm":
        return {"w": jnp.ones((d,), cfg.dtype)}
    return {"w": jnp.ones((d,), cfg.dtype), "b": jnp.zeros((d,), cfg.dtype)}


def apply_norm(cfg: ModelConfig, p, x):
    return norm_apply(cfg, p["w"], x, p.get("b"))


def activation(cfg: ModelConfig, x):
    return jax.nn.silu(x) if cfg.act == "silu" else jax.nn.gelu(x)


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    s = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)


def dense(cfg: ModelConfig, x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Linear layer with a selectable memory arrangement (the paper's
    technique as a first-class switch):

    * ``xla``  — plain jnp matmul; XLA picks layouts (production dry-run path);
    * ``bwma`` — route through the Pallas blocked-GEMM kernel: weights and
      activations move HBM->VMEM as contiguous accelerator-sized blocks
      (paper Fig. 4d).  On CPU this runs in interpret mode (small scale);
    * ``rwma`` — the row-major tiled Pallas kernel (the paper's baseline).
    """
    if cfg.gemm_backend == "xla" or w.ndim != 2:
        return x @ w
    from repro.core import blockwise as bw
    from repro.core.backend import resolve_backend
    from repro.core.layout import BlockLayout
    from repro.kernels import ops as kops

    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    blk = min(cfg.block, *x2.shape, *w.shape)
    blk = max(8, blk)
    if cfg.gemm_backend == "bwma":
        # the shared, memoized Pallas backend: its per-operator jit caches
        # persist across layers/steps, so the whole model zoo reuses one
        # compiled kernel per shape instead of re-tracing each call
        layout = BlockLayout(blk, blk)
        out = resolve_backend("pallas").matmul(
            bw.block(x2, layout), bw.block(w, layout)
        ).unblock()
    else:  # rwma
        m, k = x2.shape
        n = w.shape[1]
        if m % blk or k % blk or n % blk:
            out = x2 @ w  # row-major kernel needs divisible shapes
        else:
            # memoized jit wrapper sharing the backend's dispatch policy
            out = kops.matmul_rwma(x2, w, bm=blk, bk=blk, bn=blk)
    return out.astype(x.dtype).reshape(*lead, w.shape[1])


# --------------------------------------------------------------------------
# RoPE / M-RoPE
# --------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float) -> jnp.ndarray:
    """(dim//2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Standard RoPE.  x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)  # (d/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (B, S, d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray, positions3: jnp.ndarray, theta: float, sections: Tuple[int, ...]
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.  positions3: (3, B, S) — temporal/height/width
    position streams; ``sections`` splits the head dim's frequency pairs among
    the three streams (sum(sections) == D//2)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)  # (d/2,)
    # pick which positional stream drives each frequency index
    sec_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=d // 2
    )  # (d/2,) in {0,1,2}
    pos = positions3.astype(jnp.float32)  # (3, B, S)
    ang_all = pos[..., None] * inv  # (3, B, S, d/2)
    # select per-frequency stream: ang[b, s, i] = ang_all[sec_id[i], b, s, i]
    sel = jax.nn.one_hot(sec_id, len(sections), dtype=jnp.float32)  # (d/2, 3)
    ang = jnp.einsum("tbsf,ft->bsf", ang_all, sel)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def default_positions(batch: int, seq: int) -> jnp.ndarray:
    return jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (batch, seq))


# --------------------------------------------------------------------------
# Chunked (flash-style) attention, pure XLA
# --------------------------------------------------------------------------

def chunked_attention(
    q: jnp.ndarray,  # (B, Sq, H, Dq)
    k: jnp.ndarray,  # (B, Sk, Hkv, Dq)
    v: jnp.ndarray,  # (B, Sk, Hkv, Dv)
    *,
    causal: bool = True,
    q_offset=0,  # absolute position of q[0] (int or traced scalar)
    k_positions: Optional[jnp.ndarray] = None,  # (B, Sk) absolute key positions
    window: Optional[int] = None,  # SWA: keys with q_pos - k_pos >= window masked
    q_chunk: int = 512,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Memory-bounded attention: scan over query chunks, full K/V per chunk.

    Avoids materializing the (B, H, Sq, Sk) score tensor — with the layer scan
    + remat this is what keeps 32k prefill inside HBM.  GQA is handled by
    reshaping heads into (Hkv, group) so no K/V repetition is materialized.
    """
    B, Sq, H, Dq = q.shape
    _, Sk, Hkv, _ = k.shape
    Dv = v.shape[-1]
    g = H // Hkv
    scale = scale if scale is not None else Dq ** -0.5
    if k_positions is None:
        k_positions = jnp.broadcast_to(jnp.arange(Sk, dtype=jnp.int32)[None], (B, Sk))

    qc = min(q_chunk, Sq)
    if Sq % qc:
        qc = Sq  # fall back to single chunk for awkward sizes
    nc = Sq // qc
    qr = q.reshape(B, nc, qc, Hkv, g, Dq)

    def one_chunk(c):
        qi = qr[:, c]  # (B, qc, Hkv, g, Dq)
        s = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qi, k,
            preferred_element_type=jnp.float32,  # f32 accum, NO operand
            # converts (convert(dot(bf16)) makes XLA materialize f32 copies
            # of the whole K/V cache, hoisted out of the layer scan)
        ) * scale
        q_pos = q_offset + c * qc + jnp.arange(qc, dtype=jnp.int32)  # (qc,)
        kp = k_positions[:, None, None, None, :]  # (B,1,1,1,Sk)
        qp = q_pos[None, None, None, :, None]
        # kp >= 0 masks empty / reset cache entries (labelled -1) when an
        # explicit k_positions is passed; the default arange labels are
        # always >= 0 so the non-cached paths are unaffected
        mask = jnp.broadcast_to(kp >= 0, (B, 1, 1, qc, Sk))
        if causal:
            mask = jnp.logical_and(mask, kp <= qp)
        if window is not None:
            mask = jnp.logical_and(mask, qp - kp < window)
        s = jnp.where(mask, s, jnp.finfo(jnp.float32).min)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum("bhgqk,bkhd->bqhgd", p, v)  # (B, qc, Hkv, g, Dv)

    if nc == 1:
        out = one_chunk(0)
        return out.reshape(B, Sq, H, Dv)
    # remat each chunk: without this, AD saves every chunk's (B,H,qc,Sk)
    # softmax for the backward pass — O(S^2) memory, defeating the chunking.
    one_chunk = jax.checkpoint(one_chunk)
    outs = jax.lax.map(one_chunk, jnp.arange(nc))  # (nc, B, qc, Hkv, g, Dv)
    out = jnp.moveaxis(outs, 0, 1)  # (B, nc, qc, ...)
    return out.reshape(B, Sq, H, Dv)


def decode_attention(
    q: jnp.ndarray,  # (B, 1, H, Dq)
    k_cache: jnp.ndarray,  # (B, Sc, Hkv, Dq)
    v_cache: jnp.ndarray,  # (B, Sc, Hkv, Dv)
    k_positions: jnp.ndarray,  # (B, Sc) absolute positions; -1 = empty slot
    q_pos,  # absolute position of the new token: scalar or (B,) per slot
    *,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """One-token attention over a (possibly ring-buffer) cache."""
    B, Sc, Hkv, Dq = k_cache.shape
    H = q.shape[2]
    g = H // Hkv
    Dv = v_cache.shape[-1]
    scale = scale if scale is not None else Dq ** -0.5
    qi = q.reshape(B, Hkv, g, Dq)
    s = jnp.einsum(
        "bhgd,bkhd->bhgk", qi, k_cache,
        preferred_element_type=jnp.float32,  # see chunked_attention note
    ) * scale
    q_pos = jnp.asarray(q_pos, jnp.int32)
    if q_pos.ndim == 0:  # one shared position (static-wave decode)
        q_pos = jnp.broadcast_to(q_pos, (B,))
    qp = q_pos[:, None]  # (B, 1) — per-slot positions (continuous batching)
    valid = (k_positions >= 0) & (k_positions <= qp)
    if window is not None:
        valid = valid & (qp - k_positions < window)
    s = jnp.where(valid[:, None, None, :], s, jnp.finfo(jnp.float32).min)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache)
    return out.reshape(B, 1, H, Dv)

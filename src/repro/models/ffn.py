"""Feed-forward modules: dense (SwiGLU / GELU) and token-choice top-k MoE.

The MoE uses a sort-based capacity dispatch (no (T, E, C) one-hot tensor):
tokens are ranked within their chosen expert via argsort + searchsorted, then
scattered into an (E, C, d) buffer.  Under the production mesh the buffer is
expert-sharded (EP) so the scatter lowers to an all-to-all-ish collective —
see distributed/sharding.py for the placement rules.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.axes import constrain
from repro.models.common import dense, dense_init


# --------------------------------------------------------------------------
# Dense FFN
# --------------------------------------------------------------------------

def ffn_init(key, cfg: ModelConfig, d_ff: int = 0) -> Dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "silu":  # SwiGLU: gate + up + down
        return {
            "w_gate": dense_init(ks[0], d, f, cfg.dtype),
            "w_up": dense_init(ks[1], d, f, cfg.dtype),
            "w_down": dense_init(ks[2], f, d, cfg.dtype),
        }
    return {  # GELU MLP (whisper / bert style)
        "w_up": dense_init(ks[0], d, f, cfg.dtype),
        "b_up": jnp.zeros((f,), cfg.dtype),
        "w_down": dense_init(ks[1], f, d, cfg.dtype),
        "b_down": jnp.zeros((d,), cfg.dtype),
    }


def ffn_forward(p: Dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    if "w_gate" in p:
        gate = jax.nn.silu(dense(cfg, x, p["w_gate"]))
        return dense(cfg, gate * dense(cfg, x, p["w_up"]), p["w_down"])
    h = jax.nn.gelu(dense(cfg, x, p["w_up"]) + p["b_up"])
    return dense(cfg, h, p["w_down"]) + p["b_down"]


# --------------------------------------------------------------------------
# MoE
# --------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig) -> Dict:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),  # fp32 router
        "w_gate": (jax.random.normal(ks[1], (E, d, f), jnp.float32) * d ** -0.5
                   ).astype(cfg.dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, f), jnp.float32) * d ** -0.5
                 ).astype(cfg.dtype),
        "w_down": (jax.random.normal(ks[3], (E, f, d), jnp.float32) * f ** -0.5
                   ).astype(cfg.dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = ffn_init(ks[4], cfg, cfg.moe_d_ff * cfg.n_shared_experts)
    return p


def _moe_groups(T: int) -> int:
    """Token groups for group-limited routing — aligned to the DP shards so
    per-group sort/scatter stays device-local and the group->expert reshard
    lowers to the canonical MoE all-to-all."""
    from repro.distributed.axes import current

    pol = current()
    G = pol.dp_size if pol is not None else 1
    return G if G > 0 and T % G == 0 else 1


def moe_forward(
    p: Dict, cfg: ModelConfig, x: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out, aux_loss).

    Token-choice top-k with *group-limited* capacity (DeepSeek-style):
    tokens are split into G groups (= DP shards); each group dispatches
    independently into an (E, Cg) buffer, so the sort/scatter/gather are
    local per group and only the expert einsums cross devices (all-to-all).
    Overflow tokens drop that expert (their other choices + the shared
    experts still apply).
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    G = _moe_groups(T)
    Tg = T // G
    xg = constrain(x.reshape(G, Tg, d), ("dp", None, None))
    logits = jnp.einsum(
        "gtd,de->gte", xg.astype(jnp.float32), p["router"]
    )  # (G, Tg, E)
    probs = jax.nn.softmax(logits, -1)
    gate_vals, idx = jax.lax.top_k(probs, k)  # (G, Tg, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- load-balancing auxiliary loss (Switch-style, global) ----
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=2), axis=(0, 1)
    )
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    # ---- per-group capacity dispatch (sort-based, scatter-free) ----
    # Everything is gathers (take_along_axis) + an inverse permutation;
    # 2-D-index scatters made XLA materialize per-element u32 index tensors
    # (hundreds of GB at DeepSeek scale).
    Cg = max(8, -(-int(Tg * k * cfg.capacity_factor / E) // 8) * 8)
    flat_e = idx.reshape(G, Tg * k)
    token_of = jnp.broadcast_to(
        jnp.repeat(jnp.arange(Tg), k)[None], (G, Tg * k)
    )
    gate_flat = gate_vals.reshape(G, Tg * k)
    order = jnp.argsort(flat_e, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    start = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(E)))(sorted_e)
    rank = jnp.arange(Tg * k)[None] - jnp.take_along_axis(start, sorted_e, axis=1)
    keep = rank < Cg
    src_tok = jnp.take_along_axis(token_of, order, axis=1)  # (G, Tg*k)
    x_sorted = jnp.take_along_axis(xg, src_tok[..., None], axis=1)
    # expert buffer by *gather*: slot (e, c) reads sorted position start[e]+c
    ec = jnp.arange(E * Cg)
    e_of, c_of = ec // Cg, ec % Cg
    start_ext = jnp.concatenate(
        [start, jnp.full((G, 1), Tg * k, start.dtype)], axis=1
    )
    pos = start[:, e_of] + c_of[None]  # (G, E*Cg)
    counts = start_ext[:, e_of + 1] - start[:, e_of]
    valid = c_of[None] < jnp.minimum(counts, Cg)
    xe = jnp.take_along_axis(
        x_sorted, jnp.clip(pos, 0, Tg * k - 1)[..., None], axis=1
    ) * valid[..., None].astype(cfg.dtype)
    xe = xe.reshape(G, E, Cg, d)
    # group-sharded -> expert-sharded: the MoE all-to-all happens here
    xe = constrain(xe, (None, "ep", None, None))

    # ---- expert computation (EP einsums over E) ----
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])

    # ---- combine back to tokens (expert-sharded -> group-sharded) ----
    # (no explicit constraint: GSPMD derives the reverse all-to-all from the
    # gather-back below; forcing dp here fought the EP einsum's output
    # sharding and triggered full rematerializations)
    ye = ye.reshape(G, E * Cg, d)
    slot = jnp.where(keep, sorted_e * Cg + rank, 0)
    y_sorted = jnp.take_along_axis(ye, slot[..., None], axis=1)  # (G, Tg*k, d)
    gate_sorted = jnp.take_along_axis(gate_flat, order, axis=1)
    contrib = y_sorted * (gate_sorted * keep)[..., None].astype(cfg.dtype)
    # undo the sort: element at sorted position s belongs at flat position
    # order[s]; applying the inverse permutation restores (token, choice)
    # order, so the per-token combine is a plain reshape + sum over k.
    inv_order = jnp.argsort(order, axis=1)
    contrib = jnp.take_along_axis(contrib, inv_order[..., None], axis=1)
    out = contrib.reshape(G, Tg, k, d).sum(axis=2)
    out = constrain(out, ("dp", None, None))

    if cfg.n_shared_experts:
        out = out + ffn_forward(p["shared"], cfg, xg)
    return out.reshape(B, S, d), aux

# repro: noqa-file RPR004 -- the model math itself dispatches per family;
# the registry rule protects the serving stack, not the layer definitions
"""Model assembly: one functional LM supporting every assigned family.

Layers are grouped into homogeneous *segments* (e.g. DeepSeek-V3 = 3 dense
layers + 58 MoE layers); each segment's parameters are stacked along a
leading L axis and executed with ``jax.lax.scan`` (+ remat in training) so
the HLO stays compact enough to compile 512-device dry-runs on CPU.

Execution modes: ``train`` (loss), ``prefill`` (populate caches),
``decode`` (one token against caches).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.axes import constrain
from repro.models import adapters as A
from repro.models import attention as attn
from repro.models import ffn as ffnm
from repro.models import ssm as ssmm
from repro.models.common import apply_norm, default_positions, dense_init, norm_init

# Segment structure lives with the cache-adapter registry (the one place
# that knows which layer family uses which layout); re-exported here because
# the whole system addresses it as M.layer_segments.
layer_segments = A.layer_segments


def _attn_init(key, cfg: ModelConfig):
    return attn.mla_init(key, cfg) if cfg.attn_type == "mla" else attn.gqa_init(key, cfg)


def init_layer(key, cfg: ModelConfig, kind: str) -> Dict:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": norm_init(cfg, cfg.d_model)}
    if kind == "ssm":
        p["ssm"] = ssmm.ssm_init(ks[0], cfg)
        return p
    p["attn"] = _attn_init(ks[0], cfg)
    if kind == "hybrid":
        p["ssm"] = ssmm.ssm_init(ks[1], cfg)
    p["ln2"] = norm_init(cfg, cfg.d_model)
    if kind == "moe":
        p["moe"] = ffnm.moe_init(ks[2], cfg)
    else:
        p["ffn"] = ffnm.ffn_init(ks[2], cfg)
    return p


def layer_forward(
    cfg: ModelConfig,
    kind: str,
    p: Dict,
    x: jnp.ndarray,
    positions,
    *,
    mode: str,
    cache: Optional[Dict],
    pos_offset,
    seq_pos=None,  # (B,) per-slot absolute positions (continuous batching)
    page_table=None,  # (B, max_pages) physical page ids (paged KV cache)
    active=None,  # (B,) bool: slots whose decode writes may land
    chunk: Optional[Dict] = None,  # chunked-prefill context (mode "chunk")
) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    if chunk is not None or (mode == "decode" and seq_pos is not None):
        return _layer_forward_engine(
            cfg, kind, p, x, positions, mode=mode, cache=cache,
            pos_offset=pos_offset, seq_pos=seq_pos, page_table=page_table,
            active=active, chunk=chunk,
        )
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    h = apply_norm(cfg, p["ln1"], x)

    if kind == "ssm":
        out, st = ssmm.ssm_forward(
            p["ssm"], cfg, h, mode=mode,
            state=cache.get("ssm") if cache else None,
        )
        if st is not None:
            new_cache["ssm"] = st
        return x + out, (new_cache or None), aux

    if cfg.attn_type == "mla":
        a_out, a_cache = attn.mla_forward(
            p["attn"], cfg, h, positions, mode=mode,
            cache=cache.get("attn") if cache else None, pos_offset=pos_offset,
        )
    else:
        a_out, a_cache = attn.gqa_forward(
            p["attn"], cfg, h, positions, mode=mode,
            cache=cache.get("attn") if cache else None, pos_offset=pos_offset,
        )
    if a_cache is not None:
        new_cache["attn"] = a_cache
    if kind == "hybrid":
        s_out, st = ssmm.ssm_forward(
            p["ssm"], cfg, h, mode=mode,
            state=cache.get("ssm") if cache else None,
        )
        if st is not None:
            new_cache["ssm"] = st
        mixer_out = 0.5 * (a_out + s_out)  # Hymba: fused parallel heads
    else:
        mixer_out = a_out
    x = x + mixer_out
    h2 = apply_norm(cfg, p["ln2"], x)
    if kind == "moe":
        m_out, m_aux = ffnm.moe_forward(p["moe"], cfg, h2)
        x = x + m_out
        aux = aux + m_aux
    else:
        x = x + ffnm.ffn_forward(p["ffn"], cfg, h2)
    x = constrain(x, ("dp", None, None))
    return x, (new_cache or None), aux


def _layer_forward_engine(
    cfg: ModelConfig, kind: str, p: Dict, x, positions, *, mode, cache,
    pos_offset, seq_pos, page_table, active, chunk,
):
    """Engine-mode layer step (chunked prefill / per-slot paged decode).

    The cache semantics — pool layout, slot addressing, chunk scatter,
    decode gather, active masking — live entirely in the family's
    :class:`~repro.models.adapters.CacheAdapter`; this function only wires
    adapter outputs into the residual stream (attention first, hybrid
    fusion, cross-attention after the self mixer, then FFN/MoE).
    """
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    h = apply_norm(cfg, p["ln1"], x)

    def run(ad, sub_p, hh):
        if mode == "chunk":
            return ad.chunk(sub_p, cfg, hh, positions, cache[ad.key], chunk,
                            pos_offset)
        return ad.decode(sub_p, cfg, hh, positions, cache[ad.key],
                         seq_pos=seq_pos, page_table=page_table, active=active)

    cross = None
    outs = []
    for ad in A.adapters_for(cfg, kind):
        if ad.key == "cross":
            cross = ad  # applies after the self mixer's residual add
            continue
        out, c_new = run(ad, p[ad.param_key], h)
        new_cache[ad.key] = c_new
        outs.append(out)
    if kind == "ssm":
        return x + outs[0], new_cache, aux
    # hybrid (Hymba) fuses parallel attention + SSM heads by mean
    x = x + (outs[0] if len(outs) == 1 else 0.5 * (outs[0] + outs[1]))
    if cross is not None:
        hc = apply_norm(cfg, p["cross"]["ln"], x)
        out_c, c_cross = run(cross, p["cross"]["attn"], hc)
        new_cache["cross"] = c_cross
        x = x + out_c
    h2 = apply_norm(cfg, p["ln2"], x)
    if kind == "moe":
        m_out, m_aux = ffnm.moe_forward(p["moe"], cfg, h2)
        x = x + m_out
        aux = aux + m_aux
    else:
        x = x + ffnm.ffn_forward(p["ffn"], cfg, h2)
    x = constrain(x, ("dp", None, None))
    return x, new_cache, aux


# --------------------------------------------------------------------------
# Cache init
# --------------------------------------------------------------------------

def _layer_cache_init(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    c: Dict[str, Any] = {}
    if kind in ("dense", "moe", "hybrid"):
        if cfg.attn_type == "mla":
            c["attn"] = attn.mla_cache_init(cfg, batch, max_len)
        else:
            c["attn"] = attn.gqa_cache_init(
                cfg, batch, max_len, window_only=(cfg.attn_type == "swa")
            )
    if kind in ("ssm", "hybrid"):
        c["ssm"] = ssmm.ssm_state_init(cfg, batch)
    return c


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked-per-segment cache pytree for decode."""
    segs = {}
    for si, (kind, n) in enumerate(layer_segments(cfg)):
        one = _layer_cache_init(cfg, kind, batch, max_len)
        segs[f"seg{si}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), one
        )
    if cfg.n_encoder_layers:  # whisper: cross-attention K/V filled at prefill
        shape = (cfg.n_layers, batch, cfg.encoder_seq, cfg.n_kv_heads,
                 cfg.d_head)
        segs["seg0"]["cross"] = {
            "k": jnp.zeros(shape, cfg.dtype),
            "v": jnp.zeros(shape, cfg.dtype),
        }
    return segs


def supports_padded_prefill(cfg: ModelConfig) -> bool:
    """Families whose prefill may be right-padded to a bucketed length.

    Full-attention dense/GQA caches index token slots by absolute position
    and mask by position label, so pad keys never survive attention (they
    are causally masked during prefill and overwritten by decode before
    their label becomes reachable) — padding is bit-exact and lets prompt
    lengths share a handful of power-of-two-page jit buckets.  SWA ring
    packing and SSM final states are position-*dependent* summaries of the
    sequence end, and MoE capacity dispatch lets pad tokens steal expert
    slots from real ones, so those families keep exact prefill shapes.
    """
    return (
        cfg.attn_type == "full"
        and cfg.family == "dense"
        and cfg.n_encoder_layers == 0
        and cfg.frontend == "none"
        and not cfg.mrope_sections
    )


def init_paged_cache(
    cfg: ModelConfig, max_seqs: int, num_pages: int, page_size: int, max_len: int
):
    """Stacked-per-segment decode cache for the continuous-batching engine.

    Each segment's cache is whatever its family's adapters declare: paged
    pools share physical page ids across layers (page ids are pool-wide);
    non-paged adapters own ``max_seqs`` per-slot rows.
    """
    msg = A.unsupported_message(cfg)
    if msg is not None:
        raise NotImplementedError(msg)
    geom = A.CacheGeometry(max_seqs, num_pages, page_size, max_len)
    segs = {}
    for si, (kind, n) in enumerate(layer_segments(cfg)):
        c = {ad.key: ad.init_pool(cfg, geom) for ad in A.adapters_for(cfg, kind)}
        segs[f"seg{si}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), c
        )
    return segs


def decode_step_paged(cfg: ModelConfig, params, caches, tokens, seq_pos,
                      page_table, active=None):
    """One continuous-batching decode step (all slots advance together).

    tokens: (B, 1) int32 — last sampled token per slot (0 for idle slots);
    seq_pos: (B,) int32 — absolute position the new token occupies (0 idle);
    page_table: (B, max_pages) int32 — physical page per logical page (idle
    and unmapped entries point at the reserved null page 0);
    active: (B,) bool — slots actually decoding.  Inactive slots (idle, or
    mid-way through a chunked prefill) run the math but their cache writes
    are dropped, so the lockstep step cannot corrupt a half-prefilled slot.
    Returns (logits (B, 1, V), new caches).
    """
    h = jnp.take(params["embed"], tokens, axis=0)
    if cfg.n_encoder_layers:
        # learned decoder positions, gathered per slot (enc-dec decode)
        h = h + jnp.take(params["dec_pos"], seq_pos, axis=0)[:, None]
    positions = seq_pos[:, None]  # (B, 1) per-slot RoPE positions
    h, new_caches, _ = _run_segments(
        cfg, params, h, positions, mode="decode", caches=caches,
        pos_offset=0, remat=False, seq_pos=seq_pos, page_table=page_table,
        active=active,
    )
    h = apply_norm(cfg, params["final_norm"], h)
    return _lm_logits(cfg, params, h), new_caches


def prefill_chunk(
    cfg: ModelConfig, params, caches, tokens, slot, q_off,
    phys_tok, off_tok, table_row, last_idx,
):
    """One prompt chunk of one request against the engine's paged caches.

    The workhorse of chunked admission: ``tokens`` (1, C) are positions
    ``q_off .. q_off + C`` of one request's prompt.  Paged segments scatter
    the chunk's K/V straight into its physical pages (``phys_tok``/
    ``off_tok``, null-page-routed when past the slot's allocation) and
    attend over the slot's ``table_row`` gather; SWA rings and SSM states
    carry slot rows across chunks.  ``caches`` is the engine's full cache
    pytree and is donated by the caller's jit, so no admission ever copies
    the pool.

    Returns (logits (1, 1, V) at in-chunk index ``last_idx`` — the next-
    token distribution after the chunk's last real token, only meaningful
    on the final chunk — and the updated caches).
    """
    B, C = tokens.shape
    assert B == 1
    h = jnp.take(params["embed"], tokens, axis=0)
    positions = (q_off + jnp.arange(C, dtype=jnp.int32))[None]  # (1, C)
    if cfg.n_encoder_layers:
        # learned decoder positions for this chunk's absolute range
        h = h + jnp.take(params["dec_pos"], positions[0], axis=0)[None]
    chunk = {
        "slot": slot, "first": q_off == 0, "table_row": table_row,
        "phys_tok": phys_tok, "off_tok": off_tok,
    }
    h, new_caches, _ = _run_segments(
        cfg, params, h, positions, mode="chunk", caches=caches,
        pos_offset=q_off, remat=False, chunk=chunk,
    )
    h_last = jax.lax.dynamic_slice_in_dim(h, last_idx, 1, axis=1)
    h_last = apply_norm(cfg, params["final_norm"], h_last)
    return _lm_logits(cfg, params, h_last), new_caches


# --------------------------------------------------------------------------
# Parameter init
# --------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Dict:
    keys = jax.random.split(key, 8)
    d, V = cfg.d_model, cfg.padded_vocab  # padded for even TP shards
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (V, d), jnp.float32) * 0.02
                  ).astype(cfg.dtype),
        "final_norm": norm_init(cfg, d),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], d, V, cfg.dtype, scale=0.02)
    ki = 2
    for si, (kind, n) in enumerate(layer_segments(cfg)):
        seg_keys = jax.random.split(keys[ki], n)
        ki += 1
        params[f"seg{si}"] = jax.vmap(
            lambda k: init_layer(k, cfg, kind)
        )(seg_keys)
    if cfg.mtp_depth:
        mk = jax.random.split(keys[5], 3)
        params["mtp"] = {
            "proj": dense_init(mk[0], 2 * d, d, cfg.dtype),
            "norm_h": norm_init(cfg, d),
            "norm_e": norm_init(cfg, d),
            "layer": init_layer(mk[1], cfg, "dense"),
            "final_norm": norm_init(cfg, d),
        }
    if cfg.n_encoder_layers:
        ek = jax.random.split(keys[6], cfg.n_encoder_layers)
        params["encoder"] = jax.vmap(
            lambda k: _enc_layer_init(k, cfg)
        )(ek)
        params["enc_final_norm"] = norm_init(cfg, d)
        params["enc_pos"] = (jax.random.normal(keys[7], (cfg.encoder_seq, d),
                                               jnp.float32) * 0.02).astype(cfg.dtype)
        # decoder cross-attention weights (per decoder layer, stacked)
        ck = jax.random.split(jax.random.fold_in(key, 99), cfg.n_layers)
        params["cross"] = jax.vmap(lambda k: _cross_init(k, cfg))(ck)
        params["dec_pos"] = (jax.random.normal(jax.random.fold_in(key, 98),
                                               (cfg.max_decoder_positions, d),
                                               jnp.float32) * 0.02
                             ).astype(cfg.dtype)
    return params


def _enc_layer_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {
        "ln1": norm_init(cfg, cfg.d_model),
        "attn": attn.gqa_init(ks[0], cfg),
        "ln2": norm_init(cfg, cfg.d_model),
        "ffn": ffnm.ffn_init(ks[1], cfg),
    }


def _cross_init(key, cfg: ModelConfig):
    return {"ln": norm_init(cfg, cfg.d_model), "attn": attn.gqa_init(key, cfg)}


# --------------------------------------------------------------------------
# Forward passes
# --------------------------------------------------------------------------

def frontend_extras(cfg: ModelConfig, batch: Dict, B: int, S: int) -> Dict:
    """Fill *missing* modality inputs with stub zero embeddings (vision /
    audio frontends).  Inputs already present (e.g. a request's real
    ``audio_embeds``) are left untouched."""
    if cfg.frontend == "vision":
        batch.setdefault("vis_embeds", jnp.zeros(
            (B, cfg.n_frontend_tokens, cfg.d_model), cfg.dtype
        ))
        batch.setdefault("positions3", jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, S)
        ))
    if cfg.frontend == "audio":
        batch.setdefault("audio_embeds", jnp.zeros(
            (B, cfg.encoder_seq, cfg.d_model), cfg.dtype
        ))
    return batch


def _embed_inputs(cfg: ModelConfig, params, batch: Dict) -> Tuple[jnp.ndarray, Any]:
    tokens = batch["tokens"]
    h = jnp.take(params["embed"], tokens, axis=0)
    h = constrain(h, ("dp", None, None))
    if cfg.frontend == "vision" and "vis_embeds" in batch:
        # stub frontend: precomputed patch embeddings occupy the prefix
        v = batch["vis_embeds"].astype(h.dtype)
        h = jax.lax.dynamic_update_slice(h, v, (0, 0, 0))
    if cfg.mrope_sections and "positions3" in batch:
        positions = batch["positions3"]
    else:
        positions = default_positions(tokens.shape[0], tokens.shape[1])
    return h, positions


def _run_segments(
    cfg: ModelConfig, params, h, positions, *, mode: str, caches=None,
    pos_offset=0, remat: bool = False, seq_pos=None, page_table=None,
    active=None, chunk=None,
):
    """Scan each stacked segment; returns (h, new_caches, aux_sum)."""
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {}
    engine = chunk is not None or (mode == "decode" and seq_pos is not None)
    seg_off = 0
    for si, (kind, n) in enumerate(layer_segments(cfg)):
        stacked = params[f"seg{si}"]
        if engine and cfg.n_encoder_layers and "cross" in params:
            # enc-dec engine path: the per-layer cross-attention params ride
            # the same scan as the decoder layers they belong to (sliced to
            # this segment's share of the layer stack, matching how the
            # cross adapter splits its admission install)
            stacked = dict(stacked)
            stacked["cross"] = jax.tree.map(
                lambda a: a[seg_off:seg_off + n], params["cross"]
            )
        seg_off += n
        cache_seg = caches.get(f"seg{si}") if caches else None

        def body(carry, inp, _kind=kind):
            x = carry
            p_layer = inp[0]
            c_layer = inp[1] if cache_seg is not None else None
            x, c_new, aux = layer_forward(
                cfg, _kind, p_layer, x, positions,
                mode=mode, cache=c_layer, pos_offset=pos_offset,
                seq_pos=seq_pos, page_table=page_table,
                active=active, chunk=chunk,
            )
            if c_new is None:
                c_new = 0  # scan needs a consistent pytree; 0 = no cache
            return x, (c_new, aux)

        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable
            )
        xs = (stacked, cache_seg) if cache_seg is not None else (stacked,)
        h, (cache_out, auxs) = jax.lax.scan(body, h, xs)
        aux_total = aux_total + jnp.sum(auxs)
        if mode in ("prefill", "decode", "chunk"):
            new_caches[f"seg{si}"] = cache_out
    return h, new_caches, aux_total


def forward_train(cfg: ModelConfig, params, batch: Dict, *, remat: bool = True):
    """Returns (per-token logits, aux losses, final hidden)."""
    if cfg.n_encoder_layers:
        return _forward_encdec_train(cfg, params, batch, remat=remat)
    h, positions = _embed_inputs(cfg, params, batch)
    h, _, aux = _run_segments(cfg, params, h, positions, mode="train", remat=remat)
    h = apply_norm(cfg, params["final_norm"], h)
    logits = _lm_logits(cfg, params, h)
    return logits, aux, h


def _lm_logits(cfg: ModelConfig, params, h):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = constrain(h @ w, ("dp", None, "tp"))
    if cfg.padded_vocab != cfg.vocab_size:
        # mask pad columns so logsumexp / sampling never see them
        pad_mask = (
            jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
            < cfg.vocab_size
        )
        logits = jnp.where(pad_mask, logits, jnp.finfo(logits.dtype).min)
    return logits


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean CE over positions with label >= 0 (fp32 logsumexp).

    The label log-prob is extracted with an iota-compare masked sum instead of
    ``take_along_axis``: a vocab-dim gather forces GSPMD to all-gather the
    (B, S, V) logits when the vocab is TP-sharded, whereas the masked sum
    stays local per shard and reduces with one psum.
    """
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
        == labels[..., None]
    )
    ll = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - ll) * mask) / jnp.maximum(mask.sum(), 1.0)


def loss_fn(cfg: ModelConfig, params, batch: Dict, *, remat: bool = True):
    """Next-token LM loss (+ MoE aux, + MTP head for DeepSeek-V3)."""
    if cfg.n_encoder_layers:
        logits, aux, _ = _forward_encdec_train(cfg, params, batch, remat=remat)
        loss = cross_entropy(logits[:, :-1], batch["labels"][:, 1:])
        return loss + aux, {"ce": loss, "aux": aux}
    logits, aux, h = forward_train(cfg, params, batch, remat=remat)
    labels = batch["labels"]
    loss = cross_entropy(logits[:, :-1], labels[:, :-1])
    metrics = {"ce": loss, "aux": aux}
    if cfg.mtp_depth:
        mtp_loss = _mtp_loss(cfg, params, h, batch)
        metrics["mtp"] = mtp_loss
        loss = loss + 0.1 * mtp_loss
    return loss + aux, metrics


def _mtp_loss(cfg: ModelConfig, params, h, batch):
    """DeepSeek-V3 multi-token prediction: one extra transformer block
    predicting token t+2 from [h_t ; emb(token_{t+1})], sharing the head."""
    p = params["mtp"]
    tokens, labels = batch["tokens"], batch["labels"]
    e_next = jnp.take(params["embed"], tokens[:, 1:], axis=0)
    h_cur = h[:, :-1]
    comb = jnp.concatenate(
        [apply_norm(cfg, p["norm_h"], h_cur), apply_norm(cfg, p["norm_e"], e_next)],
        axis=-1,
    ) @ p["proj"]
    positions = default_positions(comb.shape[0], comb.shape[1])
    out, _, _ = layer_forward(
        cfg, "dense", p["layer"], comb, positions,
        mode="train", cache=None, pos_offset=0,
    )
    out = apply_norm(cfg, p["final_norm"], out)
    logits = _lm_logits(cfg, params, out)  # predicts labels shifted by +1
    return cross_entropy(logits[:, :-1], labels[:, 1:-1])


# --------------------------------------------------------------------------
# Encoder-decoder (whisper)
# --------------------------------------------------------------------------

def _encoder_forward(cfg: ModelConfig, params, audio_embeds, *, remat=False):
    h = audio_embeds.astype(cfg.dtype) + params["enc_pos"][None]
    positions = default_positions(h.shape[0], h.shape[1])

    def body(carry, p_layer):
        x = carry
        hh = apply_norm(cfg, p_layer["ln1"], x)
        a, _ = attn.gqa_forward(p_layer["attn"], cfg, hh, positions,
                                mode="train", causal=False)
        x = x + a
        x = x + ffnm.ffn_forward(
            p_layer["ffn"], cfg, apply_norm(cfg, p_layer["ln2"], x)
        )
        return x, 0

    if remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["encoder"])
    return apply_norm(cfg, params["enc_final_norm"], h)


def _dec_layer(cfg, p_layer, p_cross, x, positions, enc_out, *, mode,
               cache, pos_offset):
    new_cache = {}
    h = apply_norm(cfg, p_layer["ln1"], x)
    a, c = attn.gqa_forward(
        p_layer["attn"], cfg, h, positions, mode=mode,
        cache=cache.get("attn") if cache else None, pos_offset=pos_offset,
    )
    if c is not None:
        new_cache["attn"] = c
    x = x + a
    # cross attention (non-causal over encoder output)
    hc = apply_norm(cfg, p_cross["ln"], x)
    pc = p_cross["attn"]
    B = hc.shape[0]
    if mode == "decode" and cache is not None and "cross" in cache:
        ck, cv = cache["cross"]["k"], cache["cross"]["v"]
    else:
        ck = (enc_out @ pc["wk"]).reshape(
            B, -1, cfg.n_kv_heads, cfg.d_head)
        cv = (enc_out @ pc["wv"]).reshape(
            B, -1, cfg.n_kv_heads, cfg.d_head)
    x = x + attn.cross_attention(pc, cfg, hc, ck, cv)
    x = x + ffnm.ffn_forward(
        p_layer["ffn"], cfg, apply_norm(cfg, p_layer["ln2"], x)
    )
    return x, new_cache, (ck, cv)


def _forward_encdec_train(cfg: ModelConfig, params, batch, *, remat=True):
    enc_out = _encoder_forward(cfg, params, batch["audio_embeds"], remat=remat)
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0) + params["dec_pos"][None, :S]
    positions = default_positions(B, S)

    def body(carry, inp):
        x = carry
        p_layer, p_cross = inp
        x, _, _ = _dec_layer(cfg, p_layer, p_cross, x, positions, enc_out,
                             mode="train", cache=None, pos_offset=0)
        return x, 0

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(body, h, (params["seg0"], params["cross"]))
    h = apply_norm(cfg, params["final_norm"], h)
    return _lm_logits(cfg, params, h), jnp.zeros((), jnp.float32), h


# --------------------------------------------------------------------------
# Prefill / decode
# --------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params, batch: Dict, last_idx=None):
    """Full-sequence forward that returns (last-position logits, caches).

    ``last_idx`` (optional traced scalar) selects which position's logits
    to return — the bucketed-prefill path right-pads the prompt to a shared
    jit shape and reads the logits at the last *real* token instead of the
    last padded one (:func:`supports_padded_prefill`).
    """
    if cfg.n_encoder_layers:
        return _prefill_encdec(cfg, params, batch)
    h, positions = _embed_inputs(cfg, params, batch)
    h, caches, _ = _run_segments(
        cfg, params, h, positions, mode="prefill", remat=False
    )
    if last_idx is None:
        h = h[:, -1:]
    else:
        h = jax.lax.dynamic_slice_in_dim(h, last_idx, 1, axis=1)
    h = apply_norm(cfg, params["final_norm"], h)
    return _lm_logits(cfg, params, h), caches


def _prefill_encdec(cfg: ModelConfig, params, batch):
    enc_out = _encoder_forward(cfg, params, batch["audio_embeds"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0) + params["dec_pos"][None, :S]
    positions = default_positions(B, S)

    def body(carry, inp):
        x = carry
        p_layer, p_cross = inp
        x, c_new, (ck, cv) = _dec_layer(
            cfg, p_layer, p_cross, x, positions, enc_out,
            mode="prefill", cache=None, pos_offset=0,
        )
        c_new["cross"] = {"k": ck, "v": cv}
        return x, c_new

    h, caches_seg = jax.lax.scan(body, h, (params["seg0"], params["cross"]))
    h = apply_norm(cfg, params["final_norm"], h[:, -1:])
    return _lm_logits(cfg, params, h), {"seg0": caches_seg}


def decode_step(cfg: ModelConfig, params, caches, tokens, pos):
    """One decode step.  tokens: (B, 1) int32; pos: scalar int32 absolute
    position.  Returns (logits (B, 1, V), new caches)."""
    B = tokens.shape[0]
    h = jnp.take(params["embed"], tokens, axis=0)
    if cfg.mrope_sections:
        positions = jnp.broadcast_to(
            jnp.asarray(pos, jnp.int32), (3, B, 1)
        )
    else:
        positions = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B, 1))
    if cfg.n_encoder_layers:
        return _decode_encdec(cfg, params, caches, h, positions, pos)
    h, new_caches, _ = _run_segments(
        cfg, params, h, positions, mode="decode", caches=caches, pos_offset=pos,
        remat=False,
    )
    h = apply_norm(cfg, params["final_norm"], h)
    return _lm_logits(cfg, params, h), new_caches


def _decode_encdec(cfg: ModelConfig, params, caches, h, positions, pos):
    h = h + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1)[None]

    def body(carry, inp):
        x = carry
        p_layer, p_cross, c_layer = inp
        x, c_new, _ = _dec_layer(
            cfg, p_layer, p_cross, x, positions, None,
            mode="decode", cache=c_layer, pos_offset=pos,
        )
        c_new["cross"] = c_layer["cross"]  # immutable encoder-side K/V
        return x, c_new

    h, new_seg = jax.lax.scan(
        body, h, (params["seg0"], params["cross"], caches["seg0"])
    )
    h = apply_norm(cfg, params["final_norm"], h)
    return _lm_logits(cfg, params, h), {"seg0": new_seg}


def encdec_cross_kv(cfg: ModelConfig, params, audio_embeds):
    """Encoder forward + per-decoder-layer cross K/V projections.

    The continuous-batching engine runs this ONCE per admission and installs
    the result into the slot's immutable cross rows — chunked decoder
    prefill and decode never touch the encoder again.  Returns stacked
    {"k", "v"} of shape (n_layers, B, encoder_seq, n_kv_heads, d_head).
    """
    enc_out = _encoder_forward(cfg, params, audio_embeds)
    B = enc_out.shape[0]

    def body(carry, p_cross):
        pc = p_cross["attn"]
        ck = (enc_out @ pc["wk"]).reshape(B, -1, cfg.n_kv_heads, cfg.d_head)
        cv = (enc_out @ pc["wv"]).reshape(B, -1, cfg.n_kv_heads, cfg.d_head)
        return carry, {"k": ck, "v": cv}

    _, kv = jax.lax.scan(body, 0, params["cross"])
    return kv

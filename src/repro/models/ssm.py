"""Mamba-2 (SSD — state-space duality) block, pure JAX.

Implements the chunked SSD algorithm: within a chunk the recurrence is the
dual *quadratic* form (block GEMMs — which is where the paper's BWMA layout
applies, see DESIGN.md §Arch-applicability), across chunks a linear scan
carries the (H, P, N) state.  A naive step-by-step recurrence is provided as
the test oracle, and doubles as the decode step.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int, int, int]:
    d_in = cfg.d_inner
    H = cfg.ssm_nheads
    P = cfg.ssm_headdim
    G = cfg.ssm_ngroups
    N = cfg.ssm_state
    return d_in, H, P, G, N


def ssm_init(key, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    d_in, H, P, G, N = ssm_dims(cfg)
    conv_dim = d_in + 2 * G * N
    ks = jax.random.split(key, 5)
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_in + 2 * G * N + H, cfg.dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), jnp.float32)
                   * 0.1).astype(cfg.dtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gn_w": jnp.ones((d_in,), cfg.dtype),
        "out_proj": dense_init(ks[2], d_in, d, cfg.dtype),
    }


def ssm_state_init(cfg: ModelConfig, batch: int) -> Dict:
    d_in, H, P, G, N = ssm_dims(cfg)
    conv_dim = d_in + 2 * G * N
    return {
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), cfg.dtype),
    }


def _causal_conv(xBC: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 history: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv1d via shifted adds.  xBC: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    if history is not None:
        xpad = jnp.concatenate([history, xBC], axis=1)  # (B, K-1+S, C)
    else:
        xpad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    S = xBC.shape[1]
    acc = jnp.zeros_like(xBC, dtype=jnp.float32)
    for i in range(K):
        acc = acc + xpad[:, i : i + S].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(acc + b.astype(jnp.float32)).astype(xBC.dtype)


def _split_proj(p, cfg: ModelConfig, x):
    d_in, H, P, G, N = ssm_dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in : 2 * d_in + 2 * G * N]
    dt = zxbcdt[..., 2 * d_in + 2 * G * N :].astype(jnp.float32)  # (B, S, H)
    return z, xBC, dt


def _gated_norm(y, z, w, eps=1e-6):
    """Mamba-2 RMSNormGated: rmsnorm(y * silu(z)) * w."""
    g = (y.astype(jnp.float32)) * jax.nn.silu(z.astype(jnp.float32))
    g = g * jax.lax.rsqrt(jnp.mean(g * g, -1, keepdims=True) + eps)
    return (g * w.astype(jnp.float32)).astype(y.dtype)


def _ssd_chunks(xs, B_, C_, dA, init, Q: int):
    """SSD over ``nc`` chunks of exactly ``Q`` tokens from ``init`` state.

    xs: (B, S, H, P) *discretized* inputs (already scaled by dt); B_/C_:
    (B, S, G, N); dA: (B, S, H) log-decays; S == nc * Q.  Returns
    (y (B, S, H, P) fp32, final state (B, H, P, N) fp32).
    """
    B, S, H, P = xs.shape
    G, N = B_.shape[2], B_.shape[3]
    nc = S // Q
    hg = H // G  # heads per B/C group
    xs_c = xs.reshape(B, nc, Q, H, P)
    B_c = B_.reshape(B, nc, Q, G, N)
    C_c = C_.reshape(B, nc, Q, G, N)
    dA_c = dA.reshape(B, nc, Q, H)
    cum = jnp.cumsum(dA_c, axis=2)  # (B, nc, Q, H)
    total = cum[:, :, -1]  # (B, nc, H)

    # ---- intra-chunk (quadratic dual form: block GEMMs) ----
    # L[i, j] = exp(cum_i - cum_j) for j <= i.  Mask BEFORE the exp: the
    # upper triangle has positive exponents that overflow to inf, and
    # where(mask, inf, 0) still produces NaN gradients (0 * inf).
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    L = jnp.exp(jnp.where(tri, diff, -1e30))  # fp32; exp(-1e30) == 0
    cb = jnp.einsum("bcqgn,bckgn->bcqkg", C_c.astype(jnp.float32),
                    B_c.astype(jnp.float32))  # (B,nc,Q,Q,G)
    cb = jnp.repeat(cb, hg, axis=-1)  # (B,nc,Q,Q,H)
    scores = cb * L
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", scores, xs_c.astype(jnp.float32))

    # ---- chunk-final states ----
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)  # (B,nc,Q,H)
    # heads map to group h // hg; expand B/C to heads (G is small)
    B_heads = jnp.repeat(B_c, hg, axis=3)  # (B, nc, Q, H, N)
    S_local = jnp.einsum(
        "bcqhn,bcqhp->bchpn",
        B_heads.astype(jnp.float32) * decay_to_end[..., None],
        xs_c.astype(jnp.float32),
    )  # (B, nc, H, P, N)

    # ---- inter-chunk scan ----
    def scan_fn(carry, inp):
        s_loc, tot = inp  # (B,H,P,N), (B,H)
        new = jnp.exp(tot)[..., None, None] * carry + s_loc
        return new, carry  # emit state *entering* the chunk

    final_state, prev_states = jax.lax.scan(
        scan_fn,
        init,
        (jnp.moveaxis(S_local, 1, 0), jnp.moveaxis(total, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B, nc, H, P, N)

    C_heads = jnp.repeat(C_c, hg, axis=3)
    y_inter = jnp.einsum(
        "bcqhn,bchpn->bcqhp",
        C_heads.astype(jnp.float32) * jnp.exp(cum)[..., None],
        prev_states,
    )
    return (y_intra + y_inter).reshape(B, S, H, P), final_state


def ssm_forward(
    p: Dict,
    cfg: ModelConfig,
    x: jnp.ndarray,  # (B, S, d)
    *,
    mode: str = "train",
    state: Optional[Dict] = None,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Chunked SSD forward.  Returns (out, final_state if prefill/decode).

    ``state`` (optional) carries {"state", "conv"} from an earlier prefix so
    a prompt can be prefilled in chunks (the serving engine's chunked
    admission).  Chunking is **grid-aligned**: the SSD chunk boundaries sit
    at multiples of ``cfg.ssm_chunk`` from the start of the *prefix*, with
    one ragged remainder chunk at the end — so a sequence prefilled in any
    number of ssm_chunk-aligned pieces takes exactly the same per-chunk ops
    (and the same sequential state recurrence) as the one-shot prefill,
    keeping the two bit-identical.
    """
    if mode == "decode":
        return ssm_step(p, cfg, x, state)
    B, S, d = x.shape
    d_in, H, P, G, N = ssm_dims(cfg)
    K = cfg.ssm_conv

    z, xBC_raw, dt = _split_proj(p, cfg, x)
    hist = state["conv"] if state is not None else None
    xBC = _causal_conv(xBC_raw, p["conv_w"], p["conv_b"], history=hist)
    xs = xBC[..., :d_in].reshape(B, S, H, P)
    B_ = xBC[..., d_in : d_in + G * N].reshape(B, S, G, N)
    C_ = xBC[..., d_in + G * N :].reshape(B, S, G, N)
    dt = jax.nn.softplus(dt + p["dt_bias"])  # (B, S, H)
    a = -jnp.exp(p["A_log"])  # (H,) negative
    dA = dt * a  # (B, S, H) log-decay per step
    xs_d = xs * dt[..., None]  # discretized input

    init = (state["state"] if state is not None
            else jnp.zeros((B, H, P, N), jnp.float32))

    # grid-aligned chunking: full ssm_chunk-sized chunks + ragged remainder
    Q = min(cfg.ssm_chunk, S)
    S_main = (S // Q) * Q
    ys = []
    st = init
    if S_main:
        y_main, st = _ssd_chunks(
            xs_d[:, :S_main], B_[:, :S_main], C_[:, :S_main],
            dA[:, :S_main], st, Q,
        )
        ys.append(y_main)
    if S > S_main:
        y_rem, st = _ssd_chunks(
            xs_d[:, S_main:], B_[:, S_main:], C_[:, S_main:],
            dA[:, S_main:], st, S - S_main,
        )
        ys.append(y_rem)
    y = ys[0] if len(ys) == 1 else jnp.concatenate(ys, axis=1)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)  # skip path
    y = y.reshape(B, S, d_in).astype(x.dtype)
    out = _gated_norm(y, z, p["gn_w"]) @ p["out_proj"]

    new_state = None
    if mode in ("prefill", "decode"):
        # conv cache: last (K-1) *pre-conv* features of the full stream
        # (prefix history + this call), matching ssm_step's cache contract
        if hist is None:
            hist = jnp.zeros((B, K - 1, xBC_raw.shape[-1]), xBC_raw.dtype)
        conv_hist = jnp.concatenate([hist, xBC_raw], axis=1)[:, -(K - 1):]
        new_state = {"state": st, "conv": conv_hist}
    return out, new_state


def _group_mask(H, G):  # pragma: no cover - unused helper kept for clarity
    return jnp.ones((H,), jnp.float32)


def ssm_step(
    p: Dict, cfg: ModelConfig, x: jnp.ndarray, state: Dict
) -> Tuple[jnp.ndarray, Dict]:
    """Single-token recurrence (decode).  x: (B, 1, d)."""
    B = x.shape[0]
    d_in, H, P, G, N = ssm_dims(cfg)
    hg = H // G
    z, xBC, dt = _split_proj(p, cfg, x)
    conv_in = jnp.concatenate([state["conv"], xBC], axis=1)  # (B, K, C)
    w = p["conv_w"]
    acc = jnp.einsum("bkc,kc->bc", conv_in.astype(jnp.float32),
                     w.astype(jnp.float32))
    xBC_t = jax.nn.silu(acc + p["conv_b"].astype(jnp.float32))  # (B, C)
    xs = xBC_t[:, :d_in].reshape(B, H, P)
    B_ = xBC_t[:, d_in : d_in + G * N].reshape(B, G, N)
    C_ = xBC_t[:, d_in + G * N :].reshape(B, G, N)
    dt_t = jax.nn.softplus(dt[:, 0] + p["dt_bias"])  # (B, H)
    a = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt_t * a)  # (B, H)
    B_h = jnp.repeat(B_, hg, axis=1)  # (B, H, N)
    C_h = jnp.repeat(C_, hg, axis=1)
    dx = xs * dt_t[..., None]  # (B, H, P)
    new_state = decay[..., None, None] * state["state"] + jnp.einsum(
        "bhp,bhn->bhpn", dx.astype(jnp.float32), B_h.astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bhn->bhp", new_state, C_h.astype(jnp.float32))
    y = y + p["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, 1, d_in).astype(x.dtype)
    out = _gated_norm(y, z, p["gn_w"]) @ p["out_proj"]
    return out, {"state": new_state, "conv": conv_in[:, 1:]}


def ssm_reference(p: Dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Naive token-by-token recurrence — oracle for the chunked path."""
    B, S, d = x.shape
    st = ssm_state_init(cfg, B)
    outs = []
    for t in range(S):
        o, st = ssm_step(p, cfg, x[:, t : t + 1], st)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)

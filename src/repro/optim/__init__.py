from repro.optim.adamw import (
    OptConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
)
from repro.optim.schedules import cosine_schedule, wsd_schedule

__all__ = [
    "OptConfig",
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "global_norm",
    "cosine_schedule",
    "wsd_schedule",
]

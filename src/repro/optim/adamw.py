"""AdamW, functional, with fp32 moments over (possibly bf16) params.

Moments inherit the parameters' sharding *extended over the data axes*
(ZeRO-style) — see distributed/sharding.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # fp32 moments by default; bf16 halves optimizer HBM (used for the
    # >100B configs where fp32 moments exceed the pod's total HBM).
    moment_dtype: Any = jnp.float32


def adamw_init(params, oc: "OptConfig" = None) -> Dict[str, Any]:
    dt = oc.moment_dtype if oc is not None else jnp.float32
    def zeros(p):
        return jnp.zeros(p.shape, dt)

    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(
    grads, opt_state, params, oc: OptConfig, lr_now
) -> Tuple[Any, Dict[str, Any]]:
    """One AdamW step.  grads may be bf16; math runs in fp32."""
    grads, gnorm = clip_by_global_norm(grads, oc.grad_clip)
    step = opt_state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - oc.b1 ** t
    bc2 = 1.0 - oc.b2 ** t

    def upd(p, g, m, v):
        m_new = oc.b1 * m.astype(jnp.float32) + (1 - oc.b1) * g
        v_new = oc.b2 * v.astype(jnp.float32) + (1 - oc.b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + oc.eps) + oc.weight_decay * p.astype(
            jnp.float32
        )
        new_p = (p.astype(jnp.float32) - lr_now * delta).astype(p.dtype)
        return new_p, m_new.astype(oc.moment_dtype), v_new.astype(oc.moment_dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}

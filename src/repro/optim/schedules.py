"""LR schedules: cosine and WSD (warmup-stable-decay, MiniCPM §4)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup: int, total: int, min_frac=0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def wsd_schedule(base_lr: float, warmup: int, stable: int, decay: int,
                 min_frac=0.01):
    """Warmup -> stable plateau -> sharp exponential-ish decay (MiniCPM)."""
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        d_prog = jnp.clip((step - warmup - stable) / max(decay, 1), 0.0, 1.0)
        dec = base_lr * (min_frac ** d_prog)
        return jnp.where(
            step < warmup, warm, jnp.where(step < warmup + stable, base_lr, dec)
        )

    return lr

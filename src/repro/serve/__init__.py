from repro.serve.engine import (
    Engine,
    EngineConfig,
    ServeConfig,
    Server,
    bucket_tokens,
    frontend_extras,
    make_requests,
    run_static_waves,
)
from repro.models.adapters import (
    prefix_compute_skippable,
    prefix_shareable,
    supported_families,
    unsupported_reason,
)
from repro.serve.kvcache import (
    CacheAudit,
    PageAllocator,
    PagedCacheConfig,
    PagedKVCache,
    PrefixIndex,
)
from repro.serve.scheduler import Request, RequestStats, Scheduler

__all__ = [
    "CacheAudit",
    "Engine",
    "EngineConfig",
    "PageAllocator",
    "PagedCacheConfig",
    "PagedKVCache",
    "PrefixIndex",
    "Request",
    "RequestStats",
    "Scheduler",
    "ServeConfig",
    "Server",
    "bucket_tokens",
    "frontend_extras",
    "make_requests",
    "prefix_compute_skippable",
    "prefix_shareable",
    "run_static_waves",
    "supported_families",
    "unsupported_reason",
]

from repro.models.adapters import (
    prefix_compute_skippable,
    prefix_shareable,
    supported_families,
    unsupported_reason,
)
from repro.serve.engine import (
    Engine,
    EngineConfig,
    ServeConfig,
    Server,
    bucket_tokens,
    frontend_extras,
    make_requests,
    run_static_waves,
)
from repro.serve.kvcache import (
    CacheAudit,
    PageAllocator,
    PagedCacheConfig,
    PagedKVCache,
    PrefixIndex,
)
from repro.serve.obs import (
    MetricsRegistry,
    Observability,
    RequestStats,
    RequestTimeline,
    Span,
    build_serve_report,
    validate_chrome_trace,
)
from repro.serve.scheduler import Request, Scheduler

__all__ = [
    "CacheAudit",
    "Engine",
    "EngineConfig",
    "MetricsRegistry",
    "Observability",
    "PageAllocator",
    "PagedCacheConfig",
    "PagedKVCache",
    "PrefixIndex",
    "Request",
    "RequestStats",
    "RequestTimeline",
    "Scheduler",
    "ServeConfig",
    "Server",
    "Span",
    "bucket_tokens",
    "build_serve_report",
    "frontend_extras",
    "make_requests",
    "prefix_compute_skippable",
    "prefix_shareable",
    "run_static_waves",
    "supported_families",
    "unsupported_reason",
    "validate_chrome_trace",
]

from repro.serve.engine import (
    Engine,
    EngineConfig,
    ServeConfig,
    Server,
    bucket_tokens,
    frontend_extras,
    make_requests,
    run_static_waves,
)
from repro.models.adapters import supported_families, unsupported_reason
from repro.serve.kvcache import PageAllocator, PagedCacheConfig, PagedKVCache
from repro.serve.scheduler import Request, RequestStats, Scheduler

__all__ = [
    "Engine",
    "EngineConfig",
    "PageAllocator",
    "PagedCacheConfig",
    "PagedKVCache",
    "Request",
    "RequestStats",
    "Scheduler",
    "ServeConfig",
    "Server",
    "bucket_tokens",
    "frontend_extras",
    "make_requests",
    "run_static_waves",
    "supported_families",
    "unsupported_reason",
]

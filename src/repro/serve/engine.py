"""Batched serving engine: prefill + decode with per-family caches.

The cache layout is family-specific and chosen by the model:
  * dense/GQA  — (B, S, Hkv, dh) K/V per layer,
  * SWA        — ring buffer of ``window`` slots (O(1) memory in context),
  * MLA        — latent (r_kv + rope) cache (DeepSeek-V3's memory win),
  * SSM        — (B, H, P, N) state + conv tail (O(1)),
  * enc-dec    — decoder self cache + precomputed cross K/V.

Decode runs a jitted one-token step; sampling is greedy or temperature.
Batch slots finish independently (EOS mask) — a light continuous-batching
scheme where finished slots keep stepping on padding until the wave drains
(slot re-fill is the serving-frontend's job, out of scope here).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import axes as AX
from repro.distributed import sharding as SH
from repro.models import model as M


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512
    temperature: float = 0.0  # 0 = greedy
    eos_id: Optional[int] = None
    seed: int = 0


class Server:
    def __init__(self, cfg: ModelConfig, params, sc: ServeConfig, mesh=None):
        self.cfg, self.params, self.sc, self.mesh = cfg, params, sc, mesh
        if mesh is not None:
            with mesh, AX.policy(mesh):
                self._prefill = jax.jit(lambda p, b: M.prefill(cfg, p, b))
                self._decode = jax.jit(
                    lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos)
                )
        else:
            self._prefill = jax.jit(lambda p, b: M.prefill(cfg, p, b))
            self._decode = jax.jit(
                lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos)
            )

    def _sample(self, logits, key):
        logits = logits[:, -1].astype(jnp.float32)
        if self.sc.temperature <= 0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.sc.temperature, axis=-1
        ).astype(jnp.int32)

    def _grow_cache(self, caches, batch: int, prompt_len: int):
        """Pad prefill caches out to max_len slots (static decode shapes)."""
        full = M.init_cache(self.cfg, batch, self.sc.max_len)

        def fit(small, big):
            if small.shape == big.shape:
                return small.astype(big.dtype)
            return jax.lax.dynamic_update_slice(
                big, small.astype(big.dtype), (0,) * big.ndim
            )

        return jax.tree.map(fit, caches, full)

    def generate(self, batch: Dict, max_new_tokens: int = 32) -> np.ndarray:
        """batch: prompt inputs (tokens (B, S) + frontend extras)."""
        cfg, sc = self.cfg, self.sc
        tokens = batch["tokens"]
        B, S = tokens.shape
        assert S + max_new_tokens <= sc.max_len, "increase ServeConfig.max_len"
        logits, caches = self._prefill(self.params, batch)
        caches = self._grow_cache(caches, B, S)
        key = jax.random.PRNGKey(sc.seed)
        out = []
        done = jnp.zeros((B,), bool)
        tok = self._sample(logits, key)
        for i in range(max_new_tokens):
            out.append(tok)
            if sc.eos_id is not None:
                done = done | (tok == sc.eos_id)
                if bool(done.all()):
                    break
            key, sub = jax.random.split(key)
            logits, caches = self._decode(
                self.params, caches, tok[:, None], jnp.int32(S + i)
            )
            tok = self._sample(logits, sub)
        return np.stack([np.asarray(t) for t in out], axis=1)

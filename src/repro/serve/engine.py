"""Serving engines: static-wave batching and continuous batching.

Two engines share the model's prefill/decode functions:

* :class:`Server` — the original **static-wave** engine: one batch of
  requests prefills together, decodes in lockstep, and the wave drains
  before the next starts.  Finished slots keep stepping on padding.  Kept as
  the baseline the continuous engine is benchmarked against.
* :class:`Engine` — **continuous batching** over the block-paged KV cache
  (:mod:`repro.serve.kvcache`): a scheduler admits requests from a queue
  into batch slots as pages free up, each slot advances at its own position,
  and a finished slot is re-filled the same step.  The decode step is one
  jitted function of static shape ``(max_seqs, 1)``.

**Chunked, donating prefill**: admission feeds a prompt through the model
in page-sized chunks (:func:`repro.models.model.prefill_chunk`), each
chunk's K/V scattered straight into its physical pages by a jitted step
that *donates* the cache pytree — no admission copies the pool, and a long
prompt interleaves with the running batch's decode steps instead of
stalling it.  Chunking also bounds jit-cache growth: every prompt length
reuses one full-chunk shape plus a small set of final-chunk shapes
(power-of-two buckets for dense/GQA; exact lengths — capped by the chunk
size — where semantics require it: SWA ring packing, SSM final states).

**Shared-prefix paged KV**: physical pages are reference-counted, and a
radix prefix index over page-aligned token prefixes lets an admission
*alias* the pages of a prompt's longest cached prefix — chunked prefill
then starts mid-prompt at the first uncached page boundary, and a decode
write into a still-shared page copies-on-write (:mod:`repro.serve.kvcache`).
Shared system prompts are the common case in production traffic: the
redundant prefill they used to cost is exactly the avoidable off-chip
traffic the paper's arrangement thesis targets.

Cache families are the registry's business (:mod:`repro.models.adapters`):
one :class:`~repro.models.adapters.CacheAdapter` per layer family owns its
pool shapes, chunk scatter, decode gather, active-mask semantics and
prefix-shareability — dense/GQA K/V pages, MLA latent pages, SWA rings,
SSM state rows, enc-dec cross rows (installed once at admission).  The
engine drives adapters generically; only the vision frontend still
requires :class:`Server`.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import warnings
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.backend import resolve_backend
from repro.distributed import axes as AX
from repro.distributed import sharding as SH
from repro.models import adapters as A
from repro.models import model as M
from repro.models.model import frontend_extras  # re-exported for callers
from repro.serve.kvcache import PagedCacheConfig, PagedKVCache
from repro.serve.obs import Observability
from repro.serve.scheduler import Request, Scheduler


@dataclasses.dataclass
class ServeConfig:
    """Static-wave server knobs.

    ``prefill_bucket``: quantum (tokens) for power-of-two prompt-length
    bucketing of dense/GQA prefill — 0 derives it from ``cfg.block``, -1
    disables bucketing (one jit entry per distinct prompt length, the
    unbounded-compile-cache failure mode).  Families whose prefill
    semantics depend on exact length (SWA ring packing, SSM states, MoE
    capacity) always use exact shapes regardless.
    """

    max_len: int = 512
    temperature: float = 0.0  # 0 = greedy
    eos_id: Optional[int] = None
    seed: int = 0
    prefill_bucket: int = 0


def bucket_tokens(n: int, block: int) -> int:
    """Round a token count up to a power-of-two number of ``block``-sized
    pages — the shared jit shapes for bucketed (dense/GQA) prefill."""
    pages = max(1, math.ceil(n / block))
    return (1 << (pages - 1).bit_length()) * block


# --------------------------------------------------------------------------
# Chunk-shape closure: the jit signatures chunked admission may compile.
#
# These are module-level (not Engine methods) so the compiled-artifact
# linter (repro.analysis.jaxcheck, rule RPJ104) can statically enumerate the
# engine's expected jit-cache key set without constructing an engine — and
# fail when a code change lets a prompt length escape the closure.
# --------------------------------------------------------------------------


def resolve_chunk_size(cfg: ModelConfig, page_size: int, requested: int = 0) -> int:
    """Prefill chunk size: page-sized by default, adapter-grid-aligned
    (see :meth:`Engine._resolve_chunk`, which delegates here)."""
    grid = A.prefill_chunk_multiple(cfg)
    if requested:
        if requested < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {requested}")
        if requested % grid:
            raise ValueError(
                f"prefill_chunk {requested} must be a multiple of "
                f"the cache adapters' chunk grid {grid}"
            )
        return requested
    return math.lcm(page_size, grid)


def final_chunk_len(cfg: ModelConfig, chunk_size: int, n: int) -> int:
    """Jit shape for a final (ragged) chunk of ``n`` real tokens: bucketed
    to the next power of two for dense/GQA, exact (capped by the chunk
    size) where semantics require it (SWA rings, SSM states, MoE)."""
    if not M.supports_padded_prefill(cfg):
        return n
    return min(bucket_tokens(n, 1), chunk_size)


def chunk_plan(cfg: ModelConfig, chunk_size: int, prompt_len: int,
               cached: int = 0) -> List[int]:
    """The chunk jit shapes (token lengths) admission runs for a prompt of
    ``prompt_len`` tokens, ``cached`` of them served from the prefix cache
    (chunking resumes at the first uncached token)."""
    plan: List[int] = []
    off = cached
    while off < prompt_len:
        n = min(chunk_size, prompt_len - off)
        last = off + n >= prompt_len
        plan.append(final_chunk_len(cfg, chunk_size, n) if last else chunk_size)
        off += n
    return plan


def chunk_shape_set(cfg: ModelConfig, chunk_size: int) -> tuple:
    """Every chunk length :func:`chunk_plan` can ever emit — the closed set
    of ``prefill_chunk`` jit signatures for this (config, chunk size).
    Bucketing families: the full chunk plus each power of two below it;
    exact-shape families: every length up to the chunk size."""
    if M.supports_padded_prefill(cfg):
        shapes = {chunk_size}
        p = 1
        while p <= chunk_size:
            shapes.add(p)
            p *= 2
        return tuple(sorted(shapes))
    return tuple(range(1, chunk_size + 1))


# jitted step functions are memoized per (hashable, frozen) ModelConfig so
# every engine instance — and repeated benchmark constructions — share one
# compile cache; the mesh path builds its own closures under the mesh context
@functools.lru_cache(maxsize=None)
def _prefill_fn(cfg: ModelConfig):
    return jax.jit(functools.partial(M.prefill, cfg))


@functools.lru_cache(maxsize=None)
def _decode_fn(cfg: ModelConfig):
    return jax.jit(functools.partial(M.decode_step, cfg))


def _paged_step(cfg: ModelConfig, params, caches, tokens, seq_pos, page_table,
                active):
    logits, new_caches = M.decode_step_paged(
        cfg, params, caches, tokens, seq_pos, page_table, active
    )
    # greedy argmax on-device (same fp32 math as Server._sample): the
    # continuous engine must sync every step to make scheduling
    # decisions, so keep that sync to one small (B,) transfer
    greedy = jnp.argmax(logits[:, -1].astype(jnp.float32), -1)
    return greedy.astype(jnp.int32), logits, new_caches


def _donate_caches() -> tuple:
    # donate the cache pytree (arg 1 of the partial-bound step fns): the
    # page pool is the dominant buffer and the engine always replaces its
    # reference with the step's output, so the update must happen in place —
    # without donation every token would copy (and briefly double) the whole
    # multi-layer pool.  XLA:CPU honors donation on this jax pin (verified
    # by the aliasing regression test in tests/test_serve.py), so ask
    # everywhere.
    return (1,)


@functools.lru_cache(maxsize=None)
def _decode_paged_fn(cfg: ModelConfig):
    return jax.jit(
        functools.partial(_paged_step, cfg), donate_argnums=_donate_caches()
    )


@functools.lru_cache(maxsize=None)
def _prefill_chunk_fn(cfg: ModelConfig):
    return jax.jit(
        functools.partial(M.prefill_chunk, cfg),
        donate_argnums=_donate_caches(),
    )


def jitted_step_fns(cfg: ModelConfig) -> Dict[str, tuple]:
    """The continuous engine's jitted hot-path steps, **un-jitted**.

    ``{name: (fn, donate_argnums)}`` — the inventory the compiled-artifact
    linter (:mod:`repro.analysis.jaxcheck`) lowers and compiles ahead of
    time.  These are exactly the callables the engine wraps in
    :func:`_decode_paged_fn` / :func:`_prefill_chunk_fn`; the cache-install
    and COW steps live with the pool they mutate
    (:func:`repro.serve.kvcache.install_step` /
    :func:`repro.serve.kvcache.cow_step`).

    ``cfg.decode_backend`` selects the decode/COW execution path the steps
    trace (jnp gather oracle vs fused pallas kernels) — pass a
    ``dataclasses.replace(cfg, decode_backend="pallas")`` config to
    inventory the kernelized hot loop.
    """
    from repro.serve import kvcache as KV

    return {
        "decode_step": (functools.partial(_paged_step, cfg), _donate_caches()),
        "prefill_chunk": (
            functools.partial(M.prefill_chunk, cfg), _donate_caches()
        ),
        "cow_copy": (KV.cow_step(cfg), KV.POOL_DONATE),
        "install": (KV.install_step(cfg), KV.POOL_DONATE),
    }


class Server:
    """Static-wave batched generation (the pre-paging baseline engine)."""

    def __init__(self, cfg: ModelConfig, params, sc: ServeConfig, mesh=None):
        self.cfg, self.params, self.sc, self.mesh = cfg, params, sc, mesh
        if mesh is not None:
            # resident 2-D TP weights; the step bodies trace under the mesh
            # (AX.traced_under) so the model's activation constraints see
            # the policy — a context around jit *construction* would be gone
            # by (lazy) trace time
            params_shape = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params
            )
            self.params = jax.device_put(params, SH.named(
                mesh, SH.param_pspecs(cfg, mesh, params_shape, mode="serve")
            ))
            self._prefill = jax.jit(
                AX.traced_under(mesh, lambda p, b, *a: M.prefill(cfg, p, b, *a))
            )
            self._decode = jax.jit(AX.traced_under(
                mesh, lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos)
            ))
        else:
            self._prefill = _prefill_fn(cfg)
            self._decode = _decode_fn(cfg)

    def _sample(self, logits, key):
        logits = logits[:, -1].astype(jnp.float32)
        if self.sc.temperature <= 0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.sc.temperature, axis=-1
        ).astype(jnp.int32)

    def _grow_cache(self, caches, batch: int, prompt_len: int):
        """Pad prefill caches out to max_len slots (static decode shapes)."""
        full = M.init_cache(self.cfg, batch, self.sc.max_len)

        def fit(small, big):
            if small.shape == big.shape:
                return small.astype(big.dtype)
            return jax.lax.dynamic_update_slice(
                big, small.astype(big.dtype), (0,) * big.ndim
            )

        return jax.tree.map(fit, caches, full)

    def generate(self, batch: Dict, max_new_tokens: int = 32) -> np.ndarray:
        """batch: prompt inputs (tokens (B, S) + frontend extras)."""
        cfg, sc = self.cfg, self.sc
        tokens = batch["tokens"]
        B, S = tokens.shape
        assert S + max_new_tokens <= sc.max_len, "increase ServeConfig.max_len"
        if sc.prefill_bucket >= 0 and M.supports_padded_prefill(cfg):
            # bucket the prompt length to power-of-two pages: every length
            # shares a handful of jit entries instead of compiling its own.
            # Pad keys are causally masked during prefill and overwritten by
            # decode before their position label becomes reachable, so the
            # logits at last_idx = S - 1 (and everything after) are
            # bit-identical to the exact-shape prefill.
            quantum = sc.prefill_bucket or cfg.block
            Sp = min(bucket_tokens(S, quantum), sc.max_len)
            padded = np.zeros((B, Sp), np.int32)
            padded[:, :S] = np.asarray(tokens)
            logits, caches = self._prefill(
                self.params, {"tokens": jnp.asarray(padded)}, jnp.int32(S - 1)
            )
        else:
            logits, caches = self._prefill(self.params, batch)
        caches = self._grow_cache(caches, B, S)
        key = jax.random.PRNGKey(sc.seed)
        out = []
        done = jnp.zeros((B,), bool)
        # split BEFORE the first sample so no key is ever used twice
        key, sub = jax.random.split(key)
        tok = self._sample(logits, sub)
        for i in range(max_new_tokens):
            out.append(tok)
            if sc.eos_id is not None:
                done = done | (tok == sc.eos_id)
                if bool(done.all()):
                    break
            key, sub = jax.random.split(key)
            logits, caches = self._decode(
                self.params, caches, tok[:, None], jnp.int32(S + i)
            )
            tok = self._sample(logits, sub)
        return np.stack([np.asarray(t) for t in out], axis=1)


# --------------------------------------------------------------------------
# Continuous batching over the block-paged cache
# --------------------------------------------------------------------------

@dataclasses.dataclass
class EngineConfig:
    """Continuous-batching engine knobs.

    ``page_size=0`` derives the page from ``cfg.block`` (the accelerator
    kernel block governs the cache arrangement); ``num_pages=0`` sizes the
    pool for ``max_seqs`` full-length sequences.

    ``prefill_chunk=0`` derives the chunk from the page size (one chunk =
    one page of tokens), lifted onto each adapter's chunk grid (e.g. the
    SSD ``ssm_chunk`` grid — the alignment that keeps chunked prefill
    bit-identical to one-shot).

    ``prefill_tokens_per_step`` is the admission budget: how many prompt
    *tokens* may run per engine step before the decode batch steps — small
    values bound the latency a long prompt can inject between two decode
    steps of the running batch.  The budget is spent page-granularly (the
    chunk is the execution quantum, and chunks are page-sized), so the
    effective budget rounds up to whole chunks.  ``0`` derives it from the
    DEPRECATED chunk-count alias ``prefill_chunks_per_step`` (budget =
    chunks x chunk size); setting the alias explicitly emits a one-shot
    ``DeprecationWarning`` (leave it None for the default of 4 chunks).

    ``chunked_prefill=False`` falls back to one-shot prefill per admission
    (still installed through the jitted donating updater).

    ``prefix_sharing`` lets requests with a common page-aligned token
    prefix alias the same physical pages (radix prefix index + refcounts +
    copy-on-write divergence).  Only effective for families whose pages
    the adapter registry declares shareable (dense/GQA, MLA); stateful
    families (SWA rings, SSM rows, enc-dec) fall through to the unshared
    path, and MoE stacks alias pages but recompute every token.

    ``backend`` selects the paged-decode execution path
    (:func:`repro.core.backend.resolve_backend` name): ``"reference"``
    keeps the jnp gather->attend decode and dense COW copy; ``"pallas"``
    streams pages through the fused paged-attention / paged-copy kernels
    (compiled on TPU, interpret mode elsewhere).  Folded into
    ``cfg.decode_backend``, so every jitted step cache keys on it.
    """

    max_seqs: int = 4
    max_len: int = 128  # per-request capacity (prompt + generation)
    page_size: int = 0
    num_pages: int = 0
    chunked_prefill: bool = True
    prefill_chunk: int = 0
    prefill_tokens_per_step: int = 0  # 0: derive from the deprecated alias
    prefill_chunks_per_step: Optional[int] = None  # DEPRECATED alias
    prefix_sharing: bool = True
    backend: str = "reference"  # paged-decode path: reference | pallas
    temperature: float = 0.0  # 0 = greedy
    eos_id: Optional[int] = None
    seed: int = 0
    # run the PagedKVCache refcount auditor after every step (sanitizer /
    # debugging aid: cross-checks allocator refcounts against slot page
    # tables and the prefix index — free + index-pinned + slot-held == total)
    debug_audit: bool = False
    # deep observability: spans/counters/cheap gauges are always on (host
    # int bookkeeping at scheduling events — cannot change outputs); this
    # additionally runs the pool audit every step for the
    # free/index_pinned/slot_held gauge split and wraps the jitted
    # decode/chunk dispatches in jax.profiler.TraceAnnotation so device
    # traces line up with the host spans
    obs: bool = False


_DEFAULT_CHUNKS_PER_STEP = 4  # the alias's historical default

_chunks_alias_warned = False


def warn_prefill_chunks_deprecated() -> None:
    """One-shot DeprecationWarning for the ``prefill_chunks_per_step``
    chunk-count alias (per process; the launch driver and EngineConfig
    consumers both funnel through here)."""
    global _chunks_alias_warned
    if _chunks_alias_warned:
        return
    _chunks_alias_warned = True
    warnings.warn(
        "prefill_chunks_per_step is deprecated: the admission budget is "
        "token-level now — set prefill_tokens_per_step (the chunk-count "
        "alias still maps to chunks x chunk size, but will be removed)",
        DeprecationWarning,
        stacklevel=3,
    )


class Engine:
    """Continuous-batching serving engine (scheduler + paged KV cache)."""

    def __init__(self, cfg: ModelConfig, params, ec: EngineConfig, mesh=None):
        # fold the backend selector into the frozen config: every memoized
        # step jit (_decode_paged_fn, _cow_fn, ...) keys on the ModelConfig,
        # so reference and pallas engines coexist without cache collisions.
        # Resolve eagerly so an unknown name fails here, not mid-trace.
        resolve_backend(ec.backend)
        if ec.backend != cfg.decode_backend:
            cfg = dataclasses.replace(cfg, decode_backend=ec.backend)
        self.cfg, self.params, self.ec, self.mesh = cfg, params, ec, mesh
        if mesh is not None:
            # fail fast at construction: a model-axis size the kv-head axis
            # cannot divide would silently replicate every paged pool
            SH.validate_paged_sharding(cfg, mesh)
        # unsupported families are refused by the PagedKVCache constructor
        # (before any pool is allocated), with the registry's family list
        # recompute families (MoE stacks) rely on prefix chunks replaying
        # the publisher's exact chunk grid for bit-identical page content;
        # one-shot prefill groups the whole prompt per request, so sharing
        # is only sound there for compute-skippable families
        sharing = ec.prefix_sharing and (
            ec.chunked_prefill or A.prefix_compute_skippable(cfg)
        )
        self.kv = PagedKVCache(cfg, PagedCacheConfig(
            max_seqs=ec.max_seqs, max_len=ec.max_len,
            page_size=ec.page_size, num_pages=ec.num_pages,
            prefix_sharing=sharing,
        ), mesh=mesh)
        self.obs = Observability(deep=ec.obs, max_seqs=ec.max_seqs)
        self.sched = Scheduler(self.kv, ec.max_seqs, obs=self.obs)
        self.chunk_size = self._resolve_chunk(ec.prefill_chunk)
        if ec.prefill_tokens_per_step < 0:
            raise ValueError("prefill_tokens_per_step must be >= 0")
        chunks_alias = ec.prefill_chunks_per_step
        if chunks_alias is None:
            chunks_alias = _DEFAULT_CHUNKS_PER_STEP
        else:
            warn_prefill_chunks_deprecated()
        if ec.prefill_tokens_per_step == 0 and chunks_alias < 1:
            # the deprecated alias is only validated when it is actually used
            raise ValueError("prefill_chunks_per_step must be >= 1")
        # token-level admission budget; the deprecated chunk-count knob
        # aliases to (chunks x chunk size) when no token budget is given
        self.tokens_per_step = (
            ec.prefill_tokens_per_step or chunks_alias * self.chunk_size
        )
        # adapters installing request-level context once at admission
        # (enc-dec encoder K/V) — resolved from the registry, not by family
        self._admission_ads = A.admission_adapters(cfg)

        if mesh is not None:
            # per-instance sharded closures (the sjit idiom): explicit
            # in/out shardings so pool donation composes with GSPMD
            # partitioning, bodies traced under the mesh (AX.traced_under)
            # so activation constraints and the pallas shard_map dispatch
            # see the policy at trace time.  Small host-fed inputs (tokens,
            # positions, page tables, scalars) are replicated.
            param_sh, pool_sh, rep = SH.serve_shardings(
                cfg, mesh, params, self.kv.data
            )
            self.params = jax.device_put(params, param_sh)
            self._prefill = jax.jit(
                AX.traced_under(mesh, functools.partial(M.prefill, cfg))
            )
            self._chunk_fn = jax.jit(
                AX.traced_under(mesh, functools.partial(M.prefill_chunk, cfg)),
                in_shardings=(
                    param_sh, pool_sh, rep, rep, rep, rep, rep, rep, rep
                ),
                out_shardings=(rep, pool_sh),
                donate_argnums=_donate_caches(),
            )
            self._decode = jax.jit(
                AX.traced_under(mesh, functools.partial(_paged_step, cfg)),
                in_shardings=(param_sh, pool_sh, rep, rep, rep, rep),
                out_shardings=(rep, rep, pool_sh),
                donate_argnums=_donate_caches(),
            )
        else:
            self._prefill = _prefill_fn(cfg)
            self._chunk_fn = _prefill_chunk_fn(cfg)
            self._decode = _decode_paged_fn(cfg)
        # per-slot last sampled token, kept ON DEVICE: the greedy loop feeds
        # decode outputs straight back in, syncing to host only at
        # scheduling events (finish, preemption, EOS, temperature sampling)
        self._last_tok = jnp.zeros((ec.max_seqs,), jnp.int32)
        # deferred token log: (device (B,) greedy tokens, [(slot, req), ...])
        self._pending: List[tuple] = []
        self._rid_counter = 0
        self.step_count = 0
        self.decode_steps = 0
        self.prefill_tokens = 0
        self.prefill_chunks = 0  # chunk steps actually run (sharing skips)

    # -- request intake -----------------------------------------------------

    def submit(
        self,
        prompt: np.ndarray,
        max_new_tokens: int,
        *,
        rid: Optional[int] = None,
        arrival_step: int = 0,
        extras: Optional[Dict] = None,
    ) -> Request:
        """``extras``: per-request modality inputs beyond the token prompt
        (e.g. a (1, encoder_seq, d_model) ``audio_embeds`` for enc-dec).
        Missing entries are stub-filled at prefill time, matching the
        static-wave baseline; extras survive preemption (re-admission
        re-runs the encoder — recompute discipline)."""
        if rid is None:
            rid = self._rid_counter
        self._rid_counter = max(self._rid_counter, rid) + 1
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        req = Request(
            rid=rid, prompt=prompt, max_new_tokens=max_new_tokens,
            arrival_step=arrival_step, extras=extras,
        )
        self.sched.submit(req)
        return req

    def _extras_batch(self, req: Request) -> Dict:
        """The request's modality inputs, stub-filled where missing."""
        batch = dict(req.extras or {})
        return frontend_extras(self.cfg, batch, 1, req.prompt_len)

    # -- sampling -----------------------------------------------------------

    def _sample(self, row_logits: jnp.ndarray, req: Request) -> int:  # repro: hot-loop
        """Sample one token from a (V,) logits row (fp32, greedy or temp)."""
        lf = row_logits.astype(jnp.float32)
        if self.ec.temperature <= 0:
            # callers that can defer use the on-device greedy feedback path,
            # not _sample — this sync only runs at scheduling events
            return int(jnp.argmax(lf))  # repro: noqa RPR002 -- sanctioned sync
        # per-request, per-position key: independent of scheduling order
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.ec.seed), req.rid),
            len(req.out_tokens),
        )
        # host sampling needs the value now; the greedy path never comes here
        return int(  # repro: noqa RPR002 -- sanctioned sync
            jax.random.categorical(key, lf / self.ec.temperature)
        )

    def _append_token(self, slot: int, req: Request, tok: int) -> None:
        # the first-token milestone is recorded by obs.prefill_complete
        # (callers fire it right before sampling the first token)
        req.out_tokens.append(tok)
        self._last_tok = self._last_tok.at[slot].set(tok)
        if req.done or (self.ec.eos_id is not None and tok == self.ec.eos_id):
            self.sched.finish(slot, self.step_count)

    def _flush_pending(self) -> None:  # repro: hot-loop
        """Materialize the deferred on-device tokens into out_tokens.

        All logged arrays are already computed (or in flight) on the device,
        so this drains the async queue once instead of once per step."""
        if not self._pending:
            return
        # THE deferred-sync point: the only place the greedy decode loop
        # pays device->host, amortized over every step since the last flush
        rows = np.stack(  # repro: noqa RPR002 -- sanctioned deferred sync
            [np.asarray(g) for g, _ in self._pending]  # repro: noqa RPR002
        )
        for row, (_, running) in zip(rows, self._pending):
            for slot, req in running:
                req.out_tokens.append(int(row[slot]))  # repro: noqa RPR002 -- host ndarray
                req.n_pending -= 1
        self._pending.clear()

    # -- prefill ------------------------------------------------------------

    def _resolve_chunk(self, requested: int) -> int:
        """Prefill chunk size: page-sized by default, adapter-grid-aligned.

        Every adapter reports the grid its chunk boundaries must sit on
        (the SSD ``ssm_chunk`` grid for SSM states — the grid the one-shot
        prefill uses, so every chunk reproduces the exact per-chunk ops of
        the one-shot path, bit-exactness); attention families accept any
        boundary (grid 1).
        """
        return resolve_chunk_size(self.cfg, self.kv.page_size, requested)

    def _last_chunk_len(self, n: int) -> int:
        """Jit shape for a final (ragged) chunk of ``n`` real tokens.

        Dense/GQA buckets to the next power of two (pad keys land in the
        null page / the decode page and are masked or overwritten before
        they become visible — bit-exact); SWA ring packing and SSM final
        states need the exact length, which is still capped by the chunk
        size, so shapes stay bounded either way.
        """
        return final_chunk_len(self.cfg, self.chunk_size, n)

    def _install_admission_context(self, slot: int, req: Request) -> None:
        """Run the registry's admission-time installs for a fresh slot
        (e.g. enc-dec: one encoder pass -> immutable cross rows).  Happens
        again after a preemption — recompute discipline."""
        for ad in self._admission_ads:
            src = ad.admission_src(self.cfg, self.params,
                                   self._extras_batch(req))
            self.kv.install_partial(slot, src)

    def _prefill_one_chunk(self, slot: int, req: Request) -> int:  # repro: hot-loop
        """Feed the next chunk of a slot's prompt through the paged caches.

        The chunk step donates the cache pytree — the pool is written in
        place — and on the final chunk samples the request's first token.
        Returns the number of real prompt tokens consumed (the admission
        budget's unit).
        """
        prompt = req.effective_prompt
        off = req.prefill_pos
        n = min(self.chunk_size, len(prompt) - off)
        # full chunks share ONE jit shape; the final ragged chunk draws from
        # the small bucketed/exact shape set (bounded by the chunk size)
        n_pad = self._last_chunk_len(n) if off + n >= len(prompt) else n
        toks = np.zeros((1, n_pad), np.int32)
        toks[0, :n] = prompt[off : off + n]
        phys_tok, off_tok = self.kv.token_targets(slot, off, n_pad)
        self.obs.chunk_begin(req, self.step_count, off, n)
        with self.obs.device_span("prefill_chunk"):
            logits, self.kv.data = self._chunk_fn(
                self.params, self.kv.data, jnp.asarray(toks), jnp.int32(slot),
                jnp.int32(off), phys_tok, off_tok, self.kv.table_row(slot),
                jnp.int32(n - 1),
            )
        req.prefill_pos += n
        self.prefill_tokens += n
        self.prefill_chunks += 1
        self.obs.chunk_end(req, self.step_count)
        # publish newly completed full pages: from here on, prompts sharing
        # this prefix alias these pages instead of recomputing them
        self.kv.commit_prefix(slot, prompt, req.prefill_pos)
        if not req.prefilling:  # final chunk: sample the first token
            # close the prefill span / open decode BEFORE sampling: with
            # max_new == 1 the sampled token finishes the request, and
            # finish must close an already-open decode span
            self.obs.prefill_complete(req, self.step_count)
            self._append_token(slot, req, self._sample(logits[0, -1], req))
        return n

    def _prefill_full(self, slot: int, req: Request) -> None:
        """One-shot prefill + jitted donating install (unchunked path)."""
        prompt = req.effective_prompt
        S = len(prompt)
        extras = self._extras_batch(req)
        if M.supports_padded_prefill(self.cfg):
            # clamp to the per-slot capacity: positions past max_len can
            # never be used, so padding beyond it would only waste compute
            # and compile an oversized shape
            Sp = min(bucket_tokens(S, self.kv.page_size), self.kv.max_len)
            toks = np.zeros((1, Sp), np.int32)
            toks[0, :S] = prompt
            with self.obs.device_span("prefill_full"):
                logits, caches = self._prefill(
                    self.params, {"tokens": jnp.asarray(toks), **extras},
                    jnp.int32(S - 1),
                )
        else:
            with self.obs.device_span("prefill_full"):
                logits, caches = self._prefill(
                    self.params, {"tokens": jnp.asarray(prompt)[None], **extras}
                )
        self.kv.install_prefill(slot, caches)
        req.prefill_pos = req.prefill_target
        self.prefill_tokens += S
        self.kv.commit_prefix(slot, prompt, S)
        # span ordering as in _prefill_one_chunk: decode must be open
        # before a max_new == 1 request finishes inside _append_token
        self.obs.prefill_complete(req, self.step_count)
        self._append_token(slot, req, self._sample(logits[0, -1], req))

    # -- engine steps -------------------------------------------------------

    def _admit_and_prefill(self) -> None:  # repro: hot-loop
        admitted = self.sched.admit(self.step_count)
        if not self.ec.chunked_prefill:
            for slot, req in admitted:
                self._prefill_full(slot, req)
            return
        # request-level admission context (enc-dec encoder K/V) installs at
        # admission, not on the first chunk: a shared-prefix admission may
        # resume its chunking mid-prompt and never see offset 0
        for slot, req in admitted:
            self._install_admission_context(slot, req)
        # token budget: oldest admission first (FIFO toward first token);
        # whatever is left after the budget waits for the next engine step,
        # with the decode batch stepping in between — a max-length prompt
        # can no longer stall in-flight decodes for its whole prefill.
        # Spending is page-granular (chunks are page-sized): a chunk may
        # start while any budget remains, so a step overshoots by at most
        # one chunk.
        budget = self.tokens_per_step
        for slot, req in self.sched.prefilling:
            while budget > 0 and req.prefilling:
                budget -= self._prefill_one_chunk(slot, req)
            if budget <= 0:
                break

    def _decode_once(self) -> None:  # repro: hot-loop
        decoding = self.sched.decoding
        deficit = sum(
            self.kv.growth_deficit(slot, req.next_pos) for slot, req in decoding
        ) if decoding else 0
        # available_pages walks the prefix tree — consult it only when the
        # free list alone cannot cover the round's growth
        if deficit > self.kv.num_free_pages and deficit > self.kv.available_pages:
            # the growth round below may preempt: victims must carry their
            # full token history back to the queue, so sync first
            self._flush_pending()
        self.sched.grow_for_decode(self.step_count)
        decoding = self.sched.decoding
        self.obs.decode_batch(len(decoding))
        if not decoding:
            return
        seq_pos = np.zeros((self.ec.max_seqs,), np.int32)  # idle slots -> 0
        active = np.zeros((self.ec.max_seqs,), bool)  # idle/prefilling: False
        for slot, req in decoding:
            seq_pos[slot] = req.next_pos
            active[slot] = True
        with self.obs.device_span("decode_step"):
            greedy, logits, self.kv.data = self._decode(
                self.params, self.kv.data, self._last_tok[:, None],
                jnp.asarray(seq_pos), self.kv.page_table(), jnp.asarray(active),
            )
        self.decode_steps += 1
        if self.ec.temperature > 0:
            # host sampling needs the logits now — no deferral on this path
            for slot, req in decoding:
                self._append_token(slot, req, self._sample(logits[slot, -1], req))
            return
        self._last_tok = greedy  # feed back on-device; no host round-trip
        self._pending.append((greedy, decoding))
        for slot, req in decoding:
            req.n_pending += 1
        if self.ec.eos_id is not None:
            # early-stop decisions need token values every step
            self._flush_pending()
            for slot, req in decoding:
                if req.state == "running" and (
                    req.done or req.out_tokens[-1] == self.ec.eos_id
                ):
                    self.sched.finish(slot, self.step_count)
            return
        # max_new completion is pure length bookkeeping: no sync needed
        for slot, req in decoding:
            if req.done:
                self.sched.finish(slot, self.step_count)

    def step(self) -> None:  # repro: hot-loop
        """One engine iteration: arrivals -> admissions (prefill) -> decode."""
        t0 = self.obs.step_begin()
        self.sched.poll_arrivals(self.step_count)
        self._admit_and_prefill()
        self._decode_once()
        self.step_count += 1
        audit = None
        if self.ec.debug_audit or self.obs.deep:
            audit = self.kv.audit()
        self.obs.step_end(self, t0, audit)

    def run(self, max_steps: int = 1_000_000) -> List[Request]:
        """Drive until every submitted request finishes; returns the
        requests that finished during THIS call (rid order, stats
        populated) — a reused engine doesn't re-report earlier batches."""
        already = set(self.sched.finished)
        while self.sched.has_work():
            if self.step_count >= max_steps:
                raise RuntimeError(f"engine did not drain in {max_steps} steps")
            before = self.step_count
            self.step()
            assert self.step_count > before
        self._flush_pending()
        return [
            self.sched.finished[rid]
            for rid in sorted(set(self.sched.finished) - already)
        ]

    # -- convenience --------------------------------------------------------

    def metrics(self) -> Dict[str, Any]:
        """JSON-ready snapshot of the engine's metrics registry."""
        return self.obs.registry.snapshot()

    def export_trace(self, path: str) -> Dict[str, Any]:
        """Write the recorded spans as Chrome-trace JSON (Perfetto-loadable)
        to ``path``; returns the trace object."""
        return self.obs.export_chrome_trace(path)

    def generate(self, batch: Dict, max_new_tokens: int = 32) -> np.ndarray:
        """Drop-in for Server.generate: all prompts arrive at step 0.

        Non-token batch entries with a leading batch axis (e.g. enc-dec
        ``audio_embeds``) are split into per-request extras.  With
        ``eos_id`` set, requests that stop early are right-padded with the
        eos token so the result stays rectangular.
        """
        tokens = np.asarray(batch["tokens"])
        for b in range(tokens.shape[0]):
            extras = {
                k: np.asarray(v)[b : b + 1]
                for k, v in batch.items() if k != "tokens"
            }
            self.submit(tokens[b], max_new_tokens, extras=extras or None)
        reqs = self.run()
        # always exactly max_new columns so downstream indexing never
        # changes shape between batches (Server can return fewer only when
        # every slot eos-stops early)
        pad = self.ec.eos_id if self.ec.eos_id is not None else 0
        out = np.full((len(reqs), max_new_tokens), pad, np.int32)
        for i, r in enumerate(reqs):
            toks = r.out_tokens[:max_new_tokens]
            out[i, : len(toks)] = toks
        return out


def run_static_waves(
    server: Server, requests: Sequence[dict], max_seqs: int
) -> Dict[int, np.ndarray]:
    """Drive the static-wave :class:`Server` over a multi-request workload.

    The pre-paging serving story: requests are grouped in arrival order into
    waves of ``max_seqs``; each wave prefills together and decodes in
    lockstep for the wave's **longest** generation length (finished slots
    burn decode steps on padding), and the next wave waits for the drain.
    Used as the baseline in ``benchmarks/serve_throughput.py``.

    Requests must share one prompt length (the static engine has no ragged
    batching).  Returns {rid: generated tokens, trimmed to the request's own
    ``max_new_tokens``}.
    """
    order = sorted(requests, key=lambda r: (r["arrival_step"], r["rid"]))
    lens = {len(r["prompt"]) for r in order}
    if len(lens) > 1:
        raise ValueError(f"static waves need one prompt length, got {sorted(lens)}")
    outs: Dict[int, np.ndarray] = {}
    for w in range(0, len(order), max_seqs):
        wave = order[w : w + max_seqs]
        toks = jnp.asarray(np.stack([r["prompt"] for r in wave]))
        batch = frontend_extras(
            server.cfg, {"tokens": toks}, toks.shape[0], toks.shape[1]
        )
        out = server.generate(batch, max(r["max_new_tokens"] for r in wave))
        for r, row in zip(wave, out):
            outs[r["rid"]] = np.asarray(row[: r["max_new_tokens"]], np.int32)
    return outs


def make_requests(
    vocab_size: int,
    num_requests: int,
    *,
    prompt_len: int = 16,
    max_new: int = 32,
    mean_interarrival: float = 0.0,
    vary_lengths: bool = True,
    seed: int = 0,
) -> List[dict]:
    """Deterministic 'Poisson-ish' smoke workload: exponential inter-arrival
    gaps (in decode-step units) and per-request generation lengths, all from
    one seeded generator.  Returns plain dicts so both engines can consume."""
    rng = np.random.default_rng(seed)
    reqs, step = [], 0
    for i in range(num_requests):
        if i and mean_interarrival > 0:
            step += int(rng.exponential(mean_interarrival))
        # generation lengths spread over [2, max_new]: realistic serving
        # traffic is length-heterogeneous, which is precisely what lockstep
        # waves pay for and slot re-fill does not
        n_new = (
            int(rng.integers(2, max_new + 1)) if vary_lengths else max_new
        )
        reqs.append({
            "rid": i,
            "prompt": rng.integers(0, vocab_size, size=(prompt_len,)).astype(np.int32),
            "max_new_tokens": n_new,
            "arrival_step": step,
        })
    return reqs

"""Block-paged KV-cache manager: the paper's arrangement applied to serving.

The paper's thesis is that data should live in memory in the units the
accelerator kernel consumes.  During decode the dominant traffic is the KV
cache, so this module stores it as **pages** of ``page_size`` token slots,
where ``page_size`` defaults to the accelerator kernel block (``cfg.block``)
— one page is exactly the contiguous region a blocked attention kernel
streams per grid step.  Physical pages live in one pool per layer and are
handed to requests through:

* a **reference-counted free-list allocator** (page 0 is reserved as the
  null page — the write target for idle batch slots and the gather target
  for unmapped entries); pages are shared by aliasing, so ``ref``/``unref``
  replace a raw ``free``,
* **per-request page tables** mapping logical pages (position // page_size)
  to physical pages, gathered back into logical order at attention time
  (:func:`repro.models.attention.gqa_paged_decode`),
* a **radix prefix index** (:class:`PrefixIndex`) keyed on page-aligned
  token prefixes: admission looks up a prompt's longest cached prefix and
  installs the slot's table row by *aliasing* those physical pages
  (refcount + 1 each), chunk-prefilling only the uncached suffix.  Decode
  writes into a page whose refcount is > 1 trigger **copy-on-write**
  (:meth:`PagedKVCache.prepare_decode_write`): a fresh page is allocated,
  the partial page is copied inside a donating jit, and the table entry is
  swapped.  Prefix pages are freed LRU — and only when the free list is
  exhausted (:meth:`PrefixIndex.evict_lru`).

What a page of context *is* per layer family — K/V tensors, the MLA
latent, an SWA ring row, an SSM state row, enc-dec cross rows — is the
family's :class:`~repro.models.adapters.CacheAdapter`'s business; this
module owns the pool geometry, the page accounting, the prefix index, and
the donating install/copy jits that walk the adapter registry.  Which
families may share pages at all is the registry's call too
(:func:`repro.models.adapters.prefix_shareable` /
``prefix_compute_skippable``): dense/GQA and MLA pages are position-
indexed pure functions of the token prefix and share cleanly; SWA rings
and SSM states are slot-local and fall through to the unshared path; MoE
stacks alias pages for the memory win but recompute every token (capacity
dispatch regroups on suffix-only chunks — the documented caveat).

Host-side bookkeeping (free list, page tables, per-slot lengths) is numpy;
device state is a pytree produced by :func:`repro.models.model.init_paged_cache`
that the engine threads through its jitted decode step.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import adapters as A
from repro.models import model as M

NULL_PAGE = 0  # reserved physical page: idle-slot writes, unmapped gathers


# donate position of the cache-pool pytree in the install/COW steps below
# (argument 0 of both) — exported so the AOT inventory
# (repro.serve.engine.jitted_step_fns -> repro.analysis.jaxcheck) declares
# the same donation the runtime jits ask for
POOL_DONATE = (0,)


# the raw (un-jitted) slot-write updater: every slot write (paged scatter,
# ring row, SSM state row, cross rows) happens inside a single call whose
# cache-pool argument the runtime jit DONATES — the pool is updated in place
# instead of being copied per admission (the eager host-side `.at[].set`
# path copied the entire multi-layer pool for every request installed).
# Which write each cache entry needs is the entry's adapter's business
# (:mod:`repro.models.adapters`); this function only walks the registry.
# Partial sources install only the keys they carry (e.g. the enc-dec
# admission installs cross rows alone, before any prompt chunk runs) —
# distinct source structures get their own jit entries, shapes stay bounded.
def install_step(cfg: ModelConfig):
    def install(data, src, slot, phys_tok, off_tok):
        out = {}
        for si, (kind, _n) in enumerate(M.layer_segments(cfg)):
            seg = f"seg{si}"
            if seg not in src:
                out[seg] = data[seg]  # untouched (partial install)
                continue
            dst, new = data[seg], {}
            for ad in A.adapters_for(cfg, kind):
                if ad.key in src[seg]:
                    new[ad.key] = ad.install(
                        cfg, dst[ad.key], src[seg][ad.key], slot,
                        phys_tok, off_tok,
                    )
                else:
                    new[ad.key] = dst[ad.key]
            out[seg] = new
        return out

    return install


# one jitted donating updater per model config
@functools.lru_cache(maxsize=None)
def _install_fn(cfg: ModelConfig):
    return jax.jit(install_step(cfg), donate_argnums=POOL_DONATE)


# the raw (un-jitted) COW page copier: copies physical page ``src`` ->
# ``dst`` in every shareable paged pool (dense/GQA K/V pages, MLA latent
# pages); the runtime jit DONATES the cache pytree, so the copy-on-write of
# one page never copies (or even briefly doubles) the pool.  Page ids are
# traced scalars, so every COW event in a config's lifetime shares one
# compiled shape.
def cow_step(cfg: ModelConfig):
    """The raw COW page-copy step (jitted with donation by `_cow_fn`).

    Copies ``src`` -> ``dst`` in every *shareable paged* adapter's pools —
    non-shareable pools (rings, SSM rows, cross rows) pass through
    untouched.  Each adapter's ``copy_page`` dispatches on
    ``cfg.decode_backend``: the reference path is a dense dynamic-slice
    copy, the pallas path a scalar-prefetched single-page copy kernel;
    both are bit-exact and keep the donated pool aliased in place.
    """
    def copy(data, src, dst):
        out = {}
        for si, (kind, _n) in enumerate(M.layer_segments(cfg)):
            seg = f"seg{si}"
            new = {}
            for ad in A.adapters_for(cfg, kind):
                if ad.paged and ad.shareable:
                    new[ad.key] = ad.copy_page(cfg, data[seg][ad.key], src, dst)
                else:
                    new[ad.key] = data[seg][ad.key]
            out[seg] = new
        return out

    return copy


# one jitted donating page copier per model config: the COW step
@functools.lru_cache(maxsize=None)
def _cow_fn(cfg: ModelConfig):
    return jax.jit(cow_step(cfg), donate_argnums=POOL_DONATE)


def sharded_pool_steps(cfg: ModelConfig, mesh, pool_shardings, replicated):
    """Mesh-sharded install/COW jits for one engine instance.

    The module-level :func:`_install_fn` / :func:`_cow_fn` are mesh-
    oblivious (and shared across engines); a mesh-aware cache builds its
    own pair here, with the pool pytree's NamedShardings pinned on both
    sides of the donation (the ``sjit`` idiom: ``in_shardings`` +
    ``out_shardings`` + ``donate_argnums`` compose, so the in-place pool
    update survives sharding — verified by jaxcheck RPJ101 over the
    sharded inventory).  The traced bodies run under the mesh/policy
    context (:func:`repro.distributed.axes.traced_under`): jit traces
    lazily, so the context must wrap the body, not the jit construction.
    Install sources and page ids are small host-fed values and replicate.
    """
    from repro.distributed import axes as AX

    install = jax.jit(
        AX.traced_under(mesh, install_step(cfg)),
        in_shardings=(pool_shardings, replicated, replicated, replicated,
                      replicated),
        out_shardings=pool_shardings,
        donate_argnums=POOL_DONATE,
    )
    cow = jax.jit(
        AX.traced_under(mesh, cow_step(cfg)),
        in_shardings=(pool_shardings, replicated, replicated),
        out_shardings=pool_shardings,
        donate_argnums=POOL_DONATE,
    )
    return install, cow


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Sizing of the paged cache pool.

    ``page_size=0`` derives the page from the accelerator kernel block
    (``cfg.block``) — the paper's 'governed by the kernel size'.
    ``num_pages=0`` sizes the pool so every slot can reach ``max_len``
    (plus the null page); smaller values exercise admission control and
    preemption.
    """

    max_seqs: int = 4
    max_len: int = 128  # per-sequence token capacity (rounded up to pages)
    page_size: int = 0
    num_pages: int = 0
    # physical pages may be aliased across requests sharing a token prefix
    # (only effective for families the registry declares shareable)
    prefix_sharing: bool = True


class PageAllocator:
    """Refcounted free-list allocator over physical page ids [1, num_pages).

    A page is handed out by :meth:`alloc` with refcount 1; sharing a page
    across requests (or pinning it in the prefix index) takes another
    reference via :meth:`ref`, and :meth:`unref` replaces a raw free — the
    page returns to the free list only when its last reference drops.
    """

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError("need at least 2 pages (one real + null page)")
        self.num_pages = num_pages
        # LIFO free list: recently released (hot) pages are reused first
        self._free: List[int] = list(range(num_pages - 1, NULL_PAGE, -1))
        self._ref = [0] * num_pages  # per-page reference count
        self.pages_allocated = 0  # cumulative allocs (sharing saves these)

    @property
    def num_free(self) -> int:
        return len(self._free)

    def refcount(self, page: int) -> int:
        return self._ref[page]

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` pages at refcount 1, or None (and no change) if the
        pool is short."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            return None
        got = [self._free.pop() for _ in range(n)]
        for p in got:
            self._ref[p] = 1
        self.pages_allocated += n
        return got

    def ref(self, pages: List[int]) -> None:
        """Take one more reference on live pages (aliasing / index pin)."""
        for p in pages:
            if not (NULL_PAGE < p < self.num_pages):
                raise ValueError(f"ref of invalid page id {p}")
            if self._ref[p] < 1:
                raise ValueError(f"ref of free page {p}")
        for p in pages:
            self._ref[p] += 1

    def unref(self, pages: List[int]) -> List[int]:
        """Drop one reference per page; pages reaching zero return to the
        free list.  Returns the pages actually freed."""
        for p in pages:
            if not (NULL_PAGE < p < self.num_pages):
                raise ValueError(f"unref of invalid page id {p}")
            if self._ref[p] < 1:
                raise ValueError(f"unref of free page {p} (double free)")
        freed = []
        for p in pages:
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free.append(p)
                freed.append(p)
        return freed


class _PrefixNode:
    """One page-aligned token page in the radix prefix index."""

    __slots__ = ("key", "page", "children", "parent", "last_used")

    def __init__(self, key, page, parent, now):
        self.key = key  # tuple of page_size token ids
        self.page = page  # physical page holding these tokens' cache
        self.children: Dict[tuple, "_PrefixNode"] = {}
        self.parent: Optional["_PrefixNode"] = parent
        self.last_used = now


class PrefixIndex:
    """Radix/trie index of cached prompt prefixes, one node per full page.

    Keys are **page-aligned token prefixes**: a node at depth d holds the
    physical page caching tokens ``[d * page_size, (d+1) * page_size)`` of
    every prompt that reaches it.  The index owns one reference on each of
    its pages (taken at :meth:`insert`), so a cached prefix survives the
    requests that built it and is reclaimed **LRU, leaf-first** only when
    the allocator's free list is exhausted (:meth:`evict_lru`) — exactly
    the paper's discipline of keeping hot arranged data resident and
    spilling cold data only under pressure.
    """

    def __init__(self, page_size: int, allocator: PageAllocator):
        self.page_size = page_size
        self.allocator = allocator
        self._root: Dict[tuple, _PrefixNode] = {}
        self._clock = 0
        self._n_nodes = 0

    @property
    def num_pages(self) -> int:
        return self._n_nodes

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def lookup(self, tokens: np.ndarray) -> Tuple[List[int], int]:
        """Longest cached prefix of ``tokens``: (physical pages, matched
        token count).

        Matches whole pages while the walk holds, then — only when the
        prompt's remaining tail is shorter than a page — one partially-
        consumed child whose key *starts with* the entire tail.  A partial
        match therefore always covers the prompt to its end (matched ==
        len(tokens)): the suffix left to prefill either starts at a page
        boundary or is empty, never mid-page.
        """
        toks = np.asarray(tokens)
        n, ps = len(toks), self.page_size
        now = self._tick()
        pages: List[int] = []
        matched = 0
        children = self._root
        while matched + ps <= n:
            key = tuple(int(t) for t in toks[matched : matched + ps])
            node = children.get(key)
            if node is None:
                break
            node.last_used = now
            pages.append(node.page)
            matched += ps
            children = node.children
        tail = tuple(int(t) for t in toks[matched:])
        if 0 < len(tail) < ps and matched + len(tail) == n:
            for key, node in children.items():
                if key[: len(tail)] == tail:
                    node.last_used = now
                    pages.append(node.page)
                    matched += len(tail)
                    break
        return pages, matched

    def insert(self, tokens: np.ndarray, pages: List[int], n_tokens: int) -> None:
        """Publish the full pages covering ``tokens[:n_tokens]``.

        Walks the tree along the token path; existing nodes are kept (the
        first publisher of a prefix wins — a concurrent recompute's
        duplicate pages simply stay private to their slot), new nodes pin
        their page with one index-owned reference.
        """
        toks = np.asarray(tokens)
        ps = self.page_size
        now = self._tick()
        children, parent = self._root, None
        for pi in range(min(n_tokens, len(toks)) // ps):
            key = tuple(int(t) for t in toks[pi * ps : (pi + 1) * ps])
            node = children.get(key)
            if node is None:
                self.allocator.ref([pages[pi]])
                node = _PrefixNode(key, pages[pi], parent, now)
                children[key] = node
                self._n_nodes += 1
            else:
                node.last_used = now
            children, parent = node.children, node

    def _walk(self):
        stack = list(self._root.values())
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            yield node

    def evict_lru(self) -> Optional[int]:
        """Free the least-recently-used evictable page (leaf node whose
        page only the index still references).  Returns the freed page id,
        or None when nothing is evictable."""
        best = None
        for node in self._walk():
            if node.children or self.allocator.refcount(node.page) != 1:
                continue
            if best is None or node.last_used < best.last_used:
                best = node
        if best is None:
            return None
        siblings = best.parent.children if best.parent else self._root
        del siblings[best.key]
        self._n_nodes -= 1
        self.allocator.unref([best.page])
        return best.page

    def reclaimable_count(self, exclude=()) -> int:
        """Pages :meth:`evict_lru` could eventually free right now: nodes
        held only by the index whose whole subtree is likewise evictable
        (eviction is leaf-first, so a pinned descendant shields its
        ancestors).  ``exclude``: pages about to be aliased — they must not
        be counted as reclaimable by the very admission that needs them."""
        exclude = set(exclude)

        def rec(node) -> Tuple[bool, int]:
            ok_below, count = True, 0
            for c in node.children.values():
                ok, n = rec(c)
                ok_below &= ok
                count += n
            ok = (ok_below and node.page not in exclude
                  and self.allocator.refcount(node.page) == 1)
            return ok, count + (1 if ok else 0)

        return sum(rec(n)[1] for n in self._root.values())


class PagedKVCache:
    """Device cache pool + host page tables for the continuous-batching engine."""

    def __init__(self, cfg: ModelConfig, pc: PagedCacheConfig, mesh=None):
        msg = A.unsupported_message(cfg, hint="use Server for the rest")
        if msg is not None:
            raise NotImplementedError(msg)
        self.cfg = cfg
        self.page_size = pc.page_size or cfg.block
        self.max_seqs = pc.max_seqs
        self.max_pages_per_seq = max(1, math.ceil(pc.max_len / self.page_size))
        self.max_len = self.max_pages_per_seq * self.page_size
        num_pages = pc.num_pages or (pc.max_seqs * self.max_pages_per_seq + 1)
        self.allocator = PageAllocator(num_pages)
        # prefix sharing is a per-family capability: pages must be position-
        # indexed pure functions of the token prefix to be aliased at all,
        # and every adapter must be shareable (and MoE absent) before the
        # prefix's prefill chunks may be skipped rather than recomputed
        self.sharing = pc.prefix_sharing and A.prefix_shareable(cfg)
        self.skip_prefill = self.sharing and A.prefix_compute_skippable(cfg)
        self.index = (
            PrefixIndex(self.page_size, self.allocator) if self.sharing else None
        )
        self.data = M.init_paged_cache(
            cfg, pc.max_seqs, num_pages, self.page_size, self.max_len
        )
        # mesh-sharded pools: place every pool leaf per the adapter
        # registry's PartitionSpecs (head axis over "model" where it
        # divides) and replace the shared module-level install/COW jits
        # with per-instance sharded ones — donation + sharding compose
        self.mesh = mesh
        self.pool_shardings = None
        self._install_jit = None
        self._cow_jit = None
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            from repro.distributed import sharding as SH

            self.pool_shardings = SH.named(
                mesh, SH.paged_cache_pspecs(cfg, mesh, self.data)
            )
            self.data = jax.device_put(self.data, self.pool_shardings)
            self._install_jit, self._cow_jit = sharded_pool_steps(
                cfg, mesh, self.pool_shardings,
                NamedSharding(mesh, PartitionSpec()),
            )
        # host-side page tables; unmapped entries point at the null page
        self._table = np.zeros((pc.max_seqs, self.max_pages_per_seq), np.int32)
        self._table_dev: Optional[jnp.ndarray] = None
        self._pages: Dict[int, List[int]] = {}  # slot -> physical pages
        self._cached_tokens: Dict[int, int] = {}  # slot -> aliased prefix len
        self.pages_aliased = 0  # cumulative prefix-page aliases (stats)
        self.cow_copies = 0  # cumulative copy-on-write page copies (stats)

    # -- jitted pool steps ---------------------------------------------------

    def _install_step(self):
        """The donating install jit: the per-instance sharded one under a
        mesh, else the module-level memoized single-device one."""
        return self._install_jit if self._install_jit is not None else _install_fn(self.cfg)

    def _cow_step(self):
        """The donating COW jit (sharded per-instance under a mesh)."""
        return self._cow_jit if self._cow_jit is not None else _cow_fn(self.cfg)

    # -- accounting ---------------------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        return max(1, math.ceil(n_tokens / self.page_size))

    @property
    def num_free_pages(self) -> int:
        return self.allocator.num_free

    @property
    def available_pages(self) -> int:
        """Pages obtainable right now: the free list plus whatever LRU
        eviction of unreferenced prefix pages could reclaim."""
        extra = self.index.reclaimable_count() if self.index else 0
        return self.allocator.num_free + extra

    @property
    def prefix_cache_pages(self) -> int:
        """Physical pages currently pinned by the prefix index."""
        return self.index.num_pages if self.index else 0

    def pool_stats(self) -> Dict[str, int]:  # repro: hot-loop
        """O(1) host-int pool stats, cheap enough for every engine step
        (the per-page ``free + index_pinned + slot_held == total`` split
        needs the :meth:`audit` walk and is deep-observability only)."""
        return {
            "pages_total": self.allocator.num_pages - 1,  # excl. null page
            "pages_free": self.allocator.num_free,
            "prefix_cache_pages": self.prefix_cache_pages,
            "pages_aliased_total": self.pages_aliased,
            "cow_copies_total": self.cow_copies,
            "pages_allocated_total": self.allocator.pages_allocated,
        }

    def _lookup(self, prompt) -> Tuple[List[int], int, int]:
        """(cached prefix pages, matched tokens, prompt length).  ``prompt``
        may be a bare length (no sharing — the unit-test/legacy form) or
        the token array the prefix index needs."""
        if isinstance(prompt, (int, np.integer)):
            return [], 0, int(prompt)
        prompt = np.asarray(prompt)
        if self.index is None:
            return [], 0, len(prompt)
        pages, matched = self.index.lookup(prompt)
        if not self.skip_prefill and matched % self.page_size:
            # recompute families (MoE stacks) may alias only grouping-
            # consistent pages: prefix chunks re-run from offset 0 on the
            # same chunk grid the publisher used, so full pages carry
            # bit-identical content — but a partially consumed tail page
            # was produced under the publisher's *longer* chunk, whose
            # capacity-dispatch grouping a shorter prompt cannot
            # reproduce (the documented MoE regroup caveat).  Clamp the
            # match to the full-page walk.
            pages = pages[:-1]
            matched -= matched % self.page_size
        return pages, matched, len(prompt)

    def _alloc(self, n: int) -> Optional[List[int]]:
        """Allocate with fallback: prefix pages are evicted LRU only when
        the free list is exhausted."""
        while self.allocator.num_free < n:
            if self.index is None or self.index.evict_lru() is None:
                return None
        return self.allocator.alloc(n)

    def can_admit(self, prompt) -> bool:
        """Admission control: room for the prompt's *uncached* pages plus
        the first decode page (cached prefix pages are aliased, not
        allocated; the reclaimable count excludes them so this stays
        consistent with what :meth:`admit` can actually deliver)."""
        pages, _matched, n = self._lookup(prompt)
        need = self.pages_for(n + 1) - len(pages)
        if self.allocator.num_free >= need:
            return True  # free list suffices: skip the index walk
        extra = self.index.reclaimable_count(exclude=pages) if self.index else 0
        return self.allocator.num_free + extra >= need

    def fits(self, total_len: int) -> bool:
        """Whether a request of this total length can ever be served."""
        return (
            total_len <= self.max_len
            and self.pages_for(total_len) <= self.allocator.num_pages - 1
        )

    # -- slot lifecycle -----------------------------------------------------

    def admit(self, slot: int, prompt) -> Optional[int]:
        """Build a slot's table row for a prompt: alias the longest cached
        prefix (refcount + 1 per page), allocate the rest fresh.

        Returns the number of prompt tokens served from the prefix cache
        (0 without sharing), or None if the pool — including LRU-evictable
        prefix pages — is short.  ``prompt`` is the token array (or a bare
        length, which skips the index)."""
        assert slot not in self._pages, f"slot {slot} already occupied"
        cached, matched, n = self._lookup(prompt)
        if cached:
            # pin before allocating: the fresh-page eviction fallback must
            # not reclaim the very prefix this admission is aliasing
            self.allocator.ref(cached)
        got = self._alloc(self.pages_for(n + 1) - len(cached))
        if got is None:
            if cached:
                self.allocator.unref(cached)
            return None
        pages = cached + got
        self.pages_aliased += len(cached)
        self._pages[slot] = pages
        self._cached_tokens[slot] = matched
        row = np.zeros((self.max_pages_per_seq,), np.int32)
        row[: len(pages)] = pages
        self._table[slot] = row
        self._table_dev = None
        return matched

    def ensure_capacity(self, slot: int, next_pos: int) -> bool:
        """Grow the slot's mapping so position ``next_pos`` is writable.

        Allocates on demand, one page at a time (the vLLM discipline),
        evicting cold prefix pages LRU before giving up.  Returns False on
        OOM — the scheduler then preempts somebody.
        """
        pages = self._pages[slot]
        needed = next_pos // self.page_size + 1
        if needed > self.max_pages_per_seq:
            raise ValueError(
                f"slot {slot}: position {next_pos} exceeds max_len {self.max_len}"
            )
        while len(pages) < needed:
            got = self._alloc(1)
            if got is None:
                return False
            self._table[slot, len(pages)] = got[0]
            pages.extend(got)
            self._table_dev = None
        return True

    def prepare_decode_write(self, slot: int, next_pos: int) -> bool:  # repro: hot-loop
        """Make position ``next_pos`` privately writable: copy-on-write.

        A decode write must not land in a page other requests (or the
        prefix index) still reference.  When the target page's refcount is
        > 1, allocate a fresh page, copy the partial page inside the
        donating COW jit, swap the slot's table entry, and drop the shared
        reference.  Returns False on OOM (the scheduler preempts, exactly
        like a growth failure).  ``ensure_capacity`` must already have
        mapped ``next_pos``.
        """
        lp = next_pos // self.page_size
        page = self._pages[slot][lp]
        if self.allocator.refcount(page) == 1:
            return True
        got = self._alloc(1)
        if got is None:
            return False
        new = got[0]
        self.data = self._cow_step()(
            self.data, jnp.int32(page), jnp.int32(new)
        )
        self._pages[slot][lp] = new
        self._table[slot, lp] = new
        self._table_dev = None
        self.allocator.unref([page])
        self.cow_copies += 1
        return True

    def growth_deficit(self, slot: int, next_pos: int) -> int:
        """Pages the slot still needs to make ``next_pos`` privately
        writable (no allocation): missing table entries, plus one when the
        already-mapped target page is shared and will copy-on-write.  Lets
        the engine predict whether the coming growth round can OOM (and so
        whether a preemption flush is needed)."""
        pages = self._pages[slot]
        lp = next_pos // self.page_size
        deficit = max(0, lp + 1 - len(pages))
        if deficit == 0 and self.allocator.refcount(pages[lp]) > 1:
            deficit = 1  # COW will allocate
        return deficit

    def release(self, slot: int) -> None:
        """Drop the slot's page references (finish or preemption); pages
        also pinned by the prefix index survive for future admissions."""
        pages = self._pages.pop(slot, None)
        if pages:
            self.allocator.unref(pages)
        self._cached_tokens.pop(slot, None)
        self._table[slot] = NULL_PAGE
        self._table_dev = None

    def page_table(self) -> jnp.ndarray:  # repro: hot-loop
        """Device mirror of the page tables (re-uploaded only when dirty).

        The ``jnp.asarray`` here is a host->device upload (not a sync) and
        runs only on steps where a table entry actually changed; steady-state
        decode reuses ``_table_dev`` without touching the host array."""
        if self._table_dev is None:
            self._table_dev = jnp.asarray(self._table)
        return self._table_dev

    # -- prefill install ----------------------------------------------------

    def install_prefill(self, slot: int, prefill_caches) -> None:
        """Write one request's prefill caches into its slot.

        ``prefill_caches`` is the (batch=1) pytree from ``M.prefill``: paged
        segments scatter their K/V into the slot's physical pages; SWA rings
        and SSM states copy into the slot's row.  The source may be right-
        padded past the slot's page allocation (bucketed prefill): those
        tokens map to the null page.  Idempotent per slot — a re-admitted
        (preempted) request simply overwrites.

        All writes happen in ONE jitted call that **donates** the cache
        pytree, so installation updates the pool in place — no admission
        copies (or even briefly doubles) the multi-layer pool.
        """
        src_len = self._src_token_count(prefill_caches)
        phys_tok, off_tok = self.token_targets(slot, 0, src_len)
        self.data = self._install_step()(
            self.data, prefill_caches, jnp.int32(slot), phys_tok, off_tok
        )

    def install_partial(self, slot: int, src) -> None:
        """Install a partial source (only the segments/keys it carries) into
        a slot — e.g. the enc-dec admission's cross rows, written once
        before any prompt chunk runs.  Same donating jit discipline as
        :meth:`install_prefill`."""
        phys_tok, off_tok = self.token_targets(slot, 0, 1)  # unused by rows
        self.data = self._install_step()(
            self.data, src, jnp.int32(slot), phys_tok, off_tok
        )

    def _src_token_count(self, prefill_caches) -> int:
        """Token count of the (possibly padded) paged prefill source."""
        for si, (kind, _n) in enumerate(M.layer_segments(self.cfg)):
            seg = f"seg{si}"
            for ad in A.adapters_for(self.cfg, kind):
                if ad.paged and ad.key in prefill_caches.get(seg, {}):
                    return ad.src_tokens(prefill_caches[seg][ad.key])
        return 1  # no paged segment (SWA/SSM): targets unused

    # -- prefix cache --------------------------------------------------------

    def commit_prefix(self, slot: int, tokens: np.ndarray, n_tokens: int) -> None:
        """Publish the slot's completed full prefill pages (covering
        ``tokens[:n_tokens]``) into the prefix index.

        Called as prefill chunks complete, so a long prompt becomes
        shareable page by page — and a request preempted mid-prefill leaves
        its finished pages cached, letting re-admission *resume* the suffix
        prefill instead of recomputing (unless memory pressure evicted them
        meanwhile).  Only full pages enter the index (partial pages cannot
        be keyed page-aligned), and only tokens the host knows at prefill
        time: the prompt, plus — for a request re-admitted after a
        mid-decode preemption — the tokens it had generated, which its
        recompute prefill replays as prompt (their pages are token-pure
        cache content like any other).  Tokens still being decoded never
        enter the index."""
        if self.index is None:
            return
        self.index.insert(tokens, self._pages[slot], n_tokens)

    # -- chunk write targets -------------------------------------------------

    def token_targets(  # repro: hot-loop
        self, slot: int, start: int, n: int
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Per-token (physical page, in-page offset) for positions
        ``[start, start + n)`` of a slot.  Positions past the slot's page
        allocation (the pad tail of a bucketed prompt) are routed to the
        null page, whose content is garbage by design — as are positions
        the slot serves from *aliased* prefix pages: their cache entries
        already exist and are shared, so a recompute's (bit-identical)
        write must be dropped, not land in a page other requests read."""
        pages = np.asarray(  # repro: noqa RPR002 -- host list -> host array
            self._pages[slot], np.int64
        )
        pos = np.arange(start, start + n)
        lp = pos // self.page_size
        phys = np.where(
            (lp < len(pages)) & (pos >= self._cached_tokens.get(slot, 0)),
            pages[np.minimum(lp, len(pages) - 1)], NULL_PAGE,
        )
        return (
            jnp.asarray(phys, jnp.int32),
            jnp.asarray(pos % self.page_size, jnp.int32),
        )

    def table_row(self, slot: int) -> jnp.ndarray:
        """One slot's page-table row for the chunk-prefill gather — a slice
        of the dirty-tracked device mirror, so a multi-chunk admission does
        not re-upload the (immutable) row once per chunk."""
        return self.page_table()[slot]

    # -- stats --------------------------------------------------------------

    def cache_bytes(self) -> int:
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(self.data))

    def cache_bytes_per_device(self) -> int:
        """Upper bound on the pool bytes resident on any ONE device: for
        each leaf, the largest addressable shard (head-sharded pools divide
        by the TP factor; replicated leaves count in full).  Equals
        :meth:`cache_bytes` single-device — the benchmark's mesh gate
        asserts the ratio matches the sharded families' TP saving."""
        total = 0
        for leaf in jax.tree.leaves(self.data):
            if self.mesh is not None and hasattr(leaf, "addressable_shards"):
                total += max(s.data.nbytes for s in leaf.addressable_shards)
            else:
                total += leaf.size * leaf.dtype.itemsize
        return total

    # -- debug auditor -------------------------------------------------------

    def audit(self) -> "CacheAudit":
        """Cross-check the allocator's refcounts against the page holders.

        Every usable physical page must satisfy::

            refcount(page) == (#slots mapping it) + (1 if index-pinned)
            page in free list  <=>  refcount(page) == 0

        and the pool must balance: ``free + index_pinned + slot_held ==
        total`` (pages both index-pinned and slot-mapped count once, as
        index-pinned).  Raises ``AssertionError`` on any violation; returns
        the accounting breakdown.  Pure host bookkeeping — safe to run
        after every engine step (``EngineConfig.debug_audit``) or from
        tests as the shared refcount auditor.
        """
        alloc = self.allocator
        n = alloc.num_pages
        expected = [0] * n
        for slot, pages in self._pages.items():
            assert len(pages) <= self.max_pages_per_seq, (
                f"slot {slot} maps {len(pages)} pages > max_pages_per_seq "
                f"{self.max_pages_per_seq}"
            )
            for lp, p in enumerate(pages):
                assert NULL_PAGE < p < n, f"slot {slot} maps invalid page {p}"
                assert self._table[slot, lp] == p, (
                    f"slot {slot} local page {lp}: table says "
                    f"{self._table[slot, lp]}, _pages says {p}"
                )
                expected[p] += 1
        index_pages: set = set()
        if self.index is not None:
            for node in self.index._walk():
                p = node.page
                assert NULL_PAGE < p < n, f"prefix index pins invalid page {p}"
                assert p not in index_pages, (
                    f"prefix index pins page {p} from two nodes"
                )
                index_pages.add(p)
                expected[p] += 1
        free = set(alloc._free)
        assert len(free) == len(alloc._free), "free list contains duplicates"
        assert NULL_PAGE not in free and alloc._ref[NULL_PAGE] == 0, (
            "null page must stay unallocated and unreferenced"
        )
        for p in range(NULL_PAGE + 1, n):
            assert alloc._ref[p] == expected[p], (
                f"page {p}: refcount {alloc._ref[p]} != {expected[p]} "
                "(slot holders + index pin)"
            )
            assert (p in free) == (expected[p] == 0), (
                f"page {p}: refcount {expected[p]} inconsistent with "
                f"free-list membership ({p in free})"
            )
        slot_pages = {p for pages in self._pages.values() for p in pages}
        stats = CacheAudit(
            total=n - 1,
            free=len(free),
            index_pinned=len(index_pages),
            slot_held=len(slot_pages - index_pages),
        )
        assert stats.free + stats.index_pinned + stats.slot_held == stats.total, (
            f"page accounting does not balance: {stats}"
        )
        return stats


@dataclasses.dataclass(frozen=True)
class CacheAudit:
    """Page accounting snapshot from :meth:`PagedKVCache.audit`.

    ``total`` excludes the reserved null page; a page that is both
    index-pinned and slot-mapped counts under ``index_pinned``.
    """

    total: int
    free: int
    index_pinned: int
    slot_held: int

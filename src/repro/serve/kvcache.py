"""Block-paged KV-cache manager: the paper's arrangement applied to serving.

The paper's thesis is that data should live in memory in the units the
accelerator kernel consumes.  During decode the dominant traffic is the KV
cache, so this module stores it as **pages** of ``page_size`` token slots,
where ``page_size`` defaults to the accelerator kernel block (``cfg.block``)
— one page is exactly the contiguous region a blocked attention kernel
streams per grid step.  Physical pages live in one pool per layer and are
handed to requests through:

* a **free-list allocator** (page 0 is reserved as the null page — the write
  target for idle batch slots and the gather target for unmapped entries),
* **per-request page tables** mapping logical pages (position // page_size)
  to physical pages, gathered back into logical order at attention time
  (:func:`repro.models.attention.gqa_paged_decode`).

What a page of context *is* per layer family — K/V tensors, the MLA
latent, an SWA ring row, an SSM state row, enc-dec cross rows — is the
family's :class:`~repro.models.adapters.CacheAdapter`'s business; this
module owns the pool geometry, the page accounting, and the donating
install jit that walks the adapter registry.

Host-side bookkeeping (free list, page tables, per-slot lengths) is numpy;
device state is a pytree produced by :func:`repro.models.model.init_paged_cache`
that the engine threads through its jitted decode step.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import adapters as A
from repro.models import model as M

NULL_PAGE = 0  # reserved physical page: idle-slot writes, unmapped gathers


# one jitted donating updater per model config: every slot write (paged
# scatter, ring row, SSM state row, cross rows) happens inside a single jit
# call whose cache-pool argument is DONATED — the pool is updated in place
# instead of being copied per admission (the eager host-side `.at[].set`
# path copied the entire multi-layer pool for every request installed).
# Which write each cache entry needs is the entry's adapter's business
# (:mod:`repro.models.adapters`); this function only walks the registry.
# Partial sources install only the keys they carry (e.g. the enc-dec
# admission installs cross rows alone, before any prompt chunk runs) —
# distinct source structures get their own jit entries, shapes stay bounded.
@functools.lru_cache(maxsize=None)
def _install_fn(cfg: ModelConfig):
    def install(data, src, slot, phys_tok, off_tok):
        out = {}
        for si, (kind, _n) in enumerate(M.layer_segments(cfg)):
            seg = f"seg{si}"
            if seg not in src:
                out[seg] = data[seg]  # untouched (partial install)
                continue
            dst, new = data[seg], {}
            for ad in A.adapters_for(cfg, kind):
                if ad.key in src[seg]:
                    new[ad.key] = ad.install(
                        cfg, dst[ad.key], src[seg][ad.key], slot,
                        phys_tok, off_tok,
                    )
                else:
                    new[ad.key] = dst[ad.key]
            out[seg] = new
        return out

    return jax.jit(install, donate_argnums=(0,))


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Sizing of the paged cache pool.

    ``page_size=0`` derives the page from the accelerator kernel block
    (``cfg.block``) — the paper's 'governed by the kernel size'.
    ``num_pages=0`` sizes the pool so every slot can reach ``max_len``
    (plus the null page); smaller values exercise admission control and
    preemption.
    """

    max_seqs: int = 4
    max_len: int = 128  # per-sequence token capacity (rounded up to pages)
    page_size: int = 0
    num_pages: int = 0


class PageAllocator:
    """Free-list allocator over physical page ids [1, num_pages)."""

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError("need at least 2 pages (one real + null page)")
        self.num_pages = num_pages
        # LIFO free list: recently released (hot) pages are reused first
        self._free: List[int] = list(range(num_pages - 1, NULL_PAGE, -1))

    @property
    def num_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` pages, or None (and no change) if the pool is short."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            return None
        got = [self._free.pop() for _ in range(n)]
        return got

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if not (NULL_PAGE < p < self.num_pages):
                raise ValueError(f"freeing invalid page id {p}")
            if p in self._free:
                raise ValueError(f"double free of page {p}")
            self._free.append(p)


class PagedKVCache:
    """Device cache pool + host page tables for the continuous-batching engine."""

    def __init__(self, cfg: ModelConfig, pc: PagedCacheConfig):
        msg = A.unsupported_message(cfg, hint="use Server for the rest")
        if msg is not None:
            raise NotImplementedError(msg)
        self.cfg = cfg
        self.page_size = pc.page_size or cfg.block
        self.max_seqs = pc.max_seqs
        self.max_pages_per_seq = max(1, math.ceil(pc.max_len / self.page_size))
        self.max_len = self.max_pages_per_seq * self.page_size
        num_pages = pc.num_pages or (pc.max_seqs * self.max_pages_per_seq + 1)
        self.allocator = PageAllocator(num_pages)
        self.data = M.init_paged_cache(
            cfg, pc.max_seqs, num_pages, self.page_size, self.max_len
        )
        # host-side page tables; unmapped entries point at the null page
        self._table = np.zeros((pc.max_seqs, self.max_pages_per_seq), np.int32)
        self._table_dev: Optional[jnp.ndarray] = None
        self._pages: Dict[int, List[int]] = {}  # slot -> physical pages

    # -- accounting ---------------------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        return max(1, math.ceil(n_tokens / self.page_size))

    @property
    def num_free_pages(self) -> int:
        return self.allocator.num_free

    def can_admit(self, prompt_len: int) -> bool:
        """Admission control: room for the prompt plus the first decode page."""
        return self.allocator.num_free >= self.pages_for(prompt_len + 1)

    def fits(self, total_len: int) -> bool:
        """Whether a request of this total length can ever be served."""
        return (
            total_len <= self.max_len
            and self.pages_for(total_len) <= self.allocator.num_pages - 1
        )

    # -- slot lifecycle -----------------------------------------------------

    def admit(self, slot: int, prompt_len: int) -> bool:
        """Allocate pages + table row for a prompt.  False if pool is short."""
        assert slot not in self._pages, f"slot {slot} already occupied"
        pages = self.allocator.alloc(self.pages_for(prompt_len + 1))
        if pages is None:
            return False
        self._pages[slot] = pages
        row = np.zeros((self.max_pages_per_seq,), np.int32)
        row[: len(pages)] = pages
        self._table[slot] = row
        self._table_dev = None
        return True

    def ensure_capacity(self, slot: int, next_pos: int) -> bool:
        """Grow the slot's mapping so position ``next_pos`` is writable.

        Allocates on demand, one page at a time (the vLLM discipline).
        Returns False on OOM — the scheduler then preempts somebody.
        """
        pages = self._pages[slot]
        needed = next_pos // self.page_size + 1
        if needed > self.max_pages_per_seq:
            raise ValueError(
                f"slot {slot}: position {next_pos} exceeds max_len {self.max_len}"
            )
        while len(pages) < needed:
            got = self.allocator.alloc(1)
            if got is None:
                return False
            self._table[slot, len(pages)] = got[0]
            pages.extend(got)
            self._table_dev = None
        return True

    def growth_deficit(self, slot: int, next_pos: int) -> int:
        """Pages the slot still needs to make ``next_pos`` writable (no
        allocation).  Lets the engine predict whether the coming growth
        round can OOM (and so whether a preemption flush is needed)."""
        needed = next_pos // self.page_size + 1
        return max(0, needed - len(self._pages[slot]))

    def release(self, slot: int) -> None:
        """Return the slot's pages to the pool (finish or preemption)."""
        pages = self._pages.pop(slot, None)
        if pages:
            self.allocator.free(pages)
        self._table[slot] = NULL_PAGE
        self._table_dev = None

    def page_table(self) -> jnp.ndarray:
        """Device mirror of the page tables (re-uploaded only when dirty)."""
        if self._table_dev is None:
            self._table_dev = jnp.asarray(self._table)
        return self._table_dev

    # -- prefill install ----------------------------------------------------

    def install_prefill(self, slot: int, prefill_caches) -> None:
        """Write one request's prefill caches into its slot.

        ``prefill_caches`` is the (batch=1) pytree from ``M.prefill``: paged
        segments scatter their K/V into the slot's physical pages; SWA rings
        and SSM states copy into the slot's row.  The source may be right-
        padded past the slot's page allocation (bucketed prefill): those
        tokens map to the null page.  Idempotent per slot — a re-admitted
        (preempted) request simply overwrites.

        All writes happen in ONE jitted call that **donates** the cache
        pytree, so installation updates the pool in place — no admission
        copies (or even briefly doubles) the multi-layer pool.
        """
        src_len = self._src_token_count(prefill_caches)
        phys_tok, off_tok = self.token_targets(slot, 0, src_len)
        self.data = _install_fn(self.cfg)(
            self.data, prefill_caches, jnp.int32(slot), phys_tok, off_tok
        )

    def install_partial(self, slot: int, src) -> None:
        """Install a partial source (only the segments/keys it carries) into
        a slot — e.g. the enc-dec admission's cross rows, written once
        before any prompt chunk runs.  Same donating jit discipline as
        :meth:`install_prefill`."""
        phys_tok, off_tok = self.token_targets(slot, 0, 1)  # unused by rows
        self.data = _install_fn(self.cfg)(
            self.data, src, jnp.int32(slot), phys_tok, off_tok
        )

    def _src_token_count(self, prefill_caches) -> int:
        """Token count of the (possibly padded) paged prefill source."""
        for si, (kind, _n) in enumerate(M.layer_segments(self.cfg)):
            seg = f"seg{si}"
            for ad in A.adapters_for(self.cfg, kind):
                if ad.paged and ad.key in prefill_caches.get(seg, {}):
                    return ad.src_tokens(prefill_caches[seg][ad.key])
        return 1  # no paged segment (SWA/SSM): targets unused

    # -- chunk write targets -------------------------------------------------

    def token_targets(
        self, slot: int, start: int, n: int
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Per-token (physical page, in-page offset) for positions
        ``[start, start + n)`` of a slot.  Positions past the slot's page
        allocation (the pad tail of a bucketed prompt) are routed to the
        null page, whose content is garbage by design."""
        pages = np.asarray(self._pages[slot], np.int64)
        pos = np.arange(start, start + n)
        lp = pos // self.page_size
        phys = np.where(
            lp < len(pages), pages[np.minimum(lp, len(pages) - 1)], NULL_PAGE
        )
        return (
            jnp.asarray(phys, jnp.int32),
            jnp.asarray(pos % self.page_size, jnp.int32),
        )

    def table_row(self, slot: int) -> jnp.ndarray:
        """One slot's page-table row for the chunk-prefill gather — a slice
        of the dirty-tracked device mirror, so a multi-chunk admission does
        not re-upload the (immutable) row once per chunk."""
        return self.page_table()[slot]

    # -- stats --------------------------------------------------------------

    def cache_bytes(self) -> int:
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(self.data))

# repro: noqa-file RPR005 -- the __main__ trace-validator CLI prints its report
"""Serving-engine observability: metrics, lifecycle spans, Perfetto export.

The paper's argument is a runtime/memory-access *breakdown* — where cycles
and off-chip traffic go — and a live serving engine needs the dynamic
counterpart of that breakdown: where engine steps, pool pages, and request
wall-clock go under real scheduling.  This module is that measurement
layer, built on one hard constraint: **zero hot-loop cost**.  Everything
recorded here is host-side int/float bookkeeping captured at the same
scheduling events where the engine already syncs (admission, preemption,
finish, the deferred token flush) — never a new device round-trip.  The
recording methods are marked ``# repro: hot-loop`` so staticcheck rule
RPR002 polices that discipline, and the runtime sanitizer suite proves it
on a live engine (transfer-guarded steps with observability enabled).

Three layers:

* :class:`MetricsRegistry` — process-local counters, gauges and fixed-
  bucket histograms (engine steps, decode-batch occupancy, admission-queue
  depth, page-pool gauges, prefix-cache traffic, COW copies, preemptions,
  jit retrace counts via the ``_cache_size()`` hook).  Cheap gauges update
  every step; *deep* gauges (the ``free / index_pinned / slot_held``
  breakdown from :meth:`~repro.serve.kvcache.PagedKVCache.audit`) are
  gated behind ``EngineConfig.obs`` because the audit walks the pool.
* **Request-lifecycle spans** — each request carries a
  :class:`RequestTimeline` of phase spans recorded host-side at scheduling
  events only: ``queued`` (arrival → admission, re-opened by preemption),
  ``prefill`` (admission → first token, containing one ``prefill-chunk``
  span per chunk *dispatched*), ``decode`` (first token → finish/preempt).
  Spans nest and close exactly — a preemption closes every open span with
  ``preempted: true`` before re-queueing — and :class:`RequestStats` is a
  **derived view** over the timeline, so step-based and wall-clock timings
  (TTFT in steps AND seconds) come from the same recorded milestones
  instead of two independent bookkeeping paths.
* **Chrome-trace/Perfetto export** — :meth:`Observability.chrome_trace`
  emits one engine-step track plus one track per request (span events,
  preemption instants, counter tracks for occupancy/queue/pool), loadable
  in ``ui.perfetto.dev`` or ``chrome://tracing``.  With deep observability
  on, the engine additionally wraps its jitted decode/chunk dispatches in
  ``jax.profiler.TraceAnnotation`` so a device trace captured with
  ``jax.profiler.trace()`` lines up with the scheduler-event spans.

Wall timestamps are ``time.perf_counter()`` taken at event-recording time;
with the engine's deferred-sync design a span therefore measures *dispatch*
(host) time for async device work — the scheduling view, which is exactly
what the step-unit columns make deterministic.

Validate an exported trace (CI runs this against the serve smoke)::

    python -m repro.serve.obs trace.json
"""
from __future__ import annotations

import bisect
import contextlib
import dataclasses
import json
import time
from typing import Any, Dict, List, Optional, Tuple

# --------------------------------------------------------------------------
# Metrics registry
# --------------------------------------------------------------------------


class Counter:
    """Monotonic event count (host int)."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0

    def inc(self, n: int = 1) -> None:  # repro: hot-loop
        self.value += n


class Gauge:
    """Point-in-time value (host number); last write wins."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0

    def set(self, v) -> None:  # repro: hot-loop
        self.value = v


class Histogram:
    """Fixed-bucket histogram: ``edges`` are inclusive upper bounds, with an
    implicit overflow bucket; ``counts`` has ``len(edges) + 1`` entries."""

    __slots__ = ("name", "help", "edges", "counts", "count", "sum")

    def __init__(self, name: str, help: str = "", edges=(1, 2, 4, 8, 16, 32, 64)):
        self.name, self.help = name, help
        self.edges = tuple(sorted(edges))
        self.counts = [0] * (len(self.edges) + 1)
        self.count = 0
        self.sum = 0

    def observe(self, v) -> None:  # repro: hot-loop
        self.counts[bisect.bisect_left(self.edges, v)] += 1
        self.count += 1
        self.sum += v


class MetricsRegistry:
    """Process-local metric store: get-or-create by (kind, name).

    Names are unique per kind; re-requesting an existing metric returns the
    same object (``help``/``edges`` of the first registration win).
    """

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str, help: str = "") -> Counter:  # repro: hot-loop
        m = self._counters.get(name)
        if m is None:
            m = self._counters[name] = Counter(name, help)
        return m

    def gauge(self, name: str, help: str = "") -> Gauge:  # repro: hot-loop
        m = self._gauges.get(name)
        if m is None:
            m = self._gauges[name] = Gauge(name, help)
        return m

    def histogram(self, name: str, help: str = "", edges=None) -> Histogram:  # repro: hot-loop
        m = self._histograms.get(name)
        if m is None:
            kw = {} if edges is None else {"edges": edges}
            m = self._histograms[name] = Histogram(name, help, **kw)
        return m

    def snapshot(self) -> Dict[str, Any]:
        """JSON-ready dump of every metric (plain ints/floats/lists)."""
        return {
            "counters": {n: c.value for n, c in sorted(self._counters.items())},
            "gauges": {n: g.value for n, g in sorted(self._gauges.items())},
            "histograms": {
                n: {
                    "edges": list(h.edges),
                    "counts": list(h.counts),
                    "count": h.count,
                    "sum": h.sum,
                }
                for n, h in sorted(self._histograms.items())
            },
        }


# --------------------------------------------------------------------------
# Request-lifecycle spans
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Span:
    """One phase interval, stamped in both engine steps and wall clock."""

    name: str
    cat: str
    begin_step: int
    t_begin: float
    end_step: int = -1
    t_end: float = -1.0
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def open(self) -> bool:
        return self.end_step < 0

    @property
    def steps(self) -> int:
        return self.end_step - self.begin_step

    @property
    def wall_s(self) -> float:
        return self.t_end - self.t_begin


class RequestTimeline:
    """Span/milestone record for one request, written at scheduling events.

    ``spans`` keeps every span in begin order (closed in place); at most a
    handful are open at once (phase + current chunk) and the engine's
    event discipline closes them exactly — :meth:`close_all` (preemption,
    finish) guarantees no orphans.  ``marks`` are first-occurrence
    milestones (``arrival``/``admitted``/``first_token``/``finish``) as
    ``(step, wall)`` pairs — the single source both the step-based and the
    wall-clock derived stats read from.
    """

    __slots__ = ("spans", "instants", "marks", "_open",
                 "n_preemptions", "cached_prompt_tokens")

    def __init__(self):
        self.spans: List[Span] = []
        self.instants: List[Tuple[str, int, float, Dict[str, Any]]] = []
        self.marks: Dict[str, Tuple[int, float]] = {}
        self._open: Dict[str, Span] = {}
        self.n_preemptions = 0
        self.cached_prompt_tokens = 0

    def begin(self, name, step, t, cat="request", **attrs) -> Span:  # repro: hot-loop
        assert name not in self._open, f"span '{name}' already open"
        span = Span(name, cat, step, t, attrs=attrs)
        self.spans.append(span)
        self._open[name] = span
        return span

    def end(self, name, step, t, **attrs) -> Span:  # repro: hot-loop
        span = self._open.pop(name)
        span.end_step, span.t_end = step, t
        span.attrs.update(attrs)
        return span

    def close_all(self, step, t, **attrs) -> List[Span]:  # repro: hot-loop
        """Close every open span (preemption / finish): no orphans, ever."""
        closed = [self.end(name, step, t, **attrs) for name in list(self._open)]
        return closed

    def instant(self, name, step, t, **attrs) -> None:  # repro: hot-loop
        self.instants.append((name, step, t, attrs))

    def mark(self, name, step, t) -> bool:  # repro: hot-loop
        """Record a first-occurrence milestone; returns True if new."""
        if name in self.marks:
            return False
        self.marks[name] = (step, t)
        return True

    @property
    def open_spans(self) -> List[str]:
        return list(self._open)


class RequestStats:
    """Derived stats view over a :class:`RequestTimeline`.

    Every number here — step-based and wall-clock alike — reads from the
    same recorded span milestones, so TTFT in engine steps and TTFT in
    seconds can never drift apart (the bug this view replaced: the old
    dataclass carried independently-written ``first_token_step`` and
    ``t_first_token`` fields).  Field names match the pre-span dataclass.
    """

    __slots__ = ("_tl",)

    def __init__(self, timeline: RequestTimeline):
        self._tl = timeline

    def _step(self, name: str, default: int = -1) -> int:
        return self._tl.marks.get(name, (default, 0.0))[0]

    def _wall(self, name: str) -> float:
        return self._tl.marks.get(name, (0, 0.0))[1]

    # -- milestones (step, wall) --------------------------------------------
    @property
    def arrival_step(self) -> int:
        return self._step("arrival", 0)

    @property
    def admitted_step(self) -> int:
        return self._step("admitted")

    @property
    def first_token_step(self) -> int:
        return self._step("first_token")

    @property
    def finish_step(self) -> int:
        return self._step("finish")

    @property
    def t_arrival(self) -> float:
        return self._wall("arrival")

    @property
    def t_admitted(self) -> float:
        return self._wall("admitted")

    @property
    def t_first_token(self) -> float:
        return self._wall("first_token")

    @property
    def t_finish(self) -> float:
        return self._wall("finish")

    # -- lifecycle counts ----------------------------------------------------
    @property
    def n_preemptions(self) -> int:
        return self._tl.n_preemptions

    @property
    def cached_prompt_tokens(self) -> int:
        return self._tl.cached_prompt_tokens

    # -- derived -------------------------------------------------------------
    @property
    def queue_steps(self) -> int:
        return self.admitted_step - self.arrival_step

    @property
    def ttft_steps(self) -> int:
        """First-token latency in engine steps (deterministic units)."""
        return self.first_token_step - self.arrival_step

    @property
    def ttft_s(self) -> float:
        """First-token latency in wall seconds, from the same milestones."""
        return self.t_first_token - self.t_arrival

    def decode_tok_s(self, n_generated: int) -> float:
        dt = self.t_finish - self.t_first_token
        return (n_generated - 1) / dt if dt > 0 and n_generated > 1 else float("inf")


# --------------------------------------------------------------------------
# The engine-facing recorder
# --------------------------------------------------------------------------

_NULL_CTX = contextlib.nullcontext()

# cumulative engine/pool values mirrored into counters each step:
# (counter name, attribute path resolved in Observability.step_end)
_OCCUPANCY_EDGES_DEFAULT = tuple(range(17))


class Observability:
    """Per-engine metrics + span recorder, fed at scheduling events.

    ``deep=False`` (the default) records the always-cheap layer: counters,
    cheap gauges, spans — pure host int bookkeeping.  ``deep=True``
    (``EngineConfig.obs``) additionally runs the pool-accounting audit
    every step (``pages_free/index_pinned/slot_held`` gauges) and wraps
    the engine's jitted dispatches in ``jax.profiler.TraceAnnotation`` so
    device traces line up with these host spans.  Neither mode touches
    device values: enabling observability cannot change engine outputs.
    """

    def __init__(self, deep: bool = False, max_seqs: int = 0,
                 max_step_spans: int = 200_000):
        self.deep = deep
        self.registry = MetricsRegistry()
        self._clock = time.perf_counter
        self.t0 = self._clock()
        self.timelines: Dict[int, RequestTimeline] = {}  # rid -> timeline
        self.step_spans: List[Span] = []
        self.max_step_spans = max_step_spans
        self._last_decode_batch = 0
        r = self.registry
        # pre-register the full metric set so snapshots are shape-stable
        # between deep on/off runs (deep only changes gauge VALUES)
        for name, help in (
            ("engine_steps_total", "engine iterations run"),
            ("decode_steps_total", "batched decode dispatches"),
            ("prefill_tokens_total", "prompt tokens prefilled (chunk or one-shot)"),
            ("prefill_chunks_total", "prefill chunk dispatches"),
            ("admissions_total", "requests admitted to a slot (incl. re-admissions)"),
            ("finished_total", "requests finished"),
            ("preemptions_total", "requests preempted (LIFO, recompute)"),
            ("prompt_tokens_total", "effective prompt tokens across admissions"),
            ("prefix_cached_tokens_total", "prompt tokens served from the prefix cache"),
            ("prefix_pages_aliased_total", "physical pages aliased at admission"),
            ("cow_copies_total", "copy-on-write page copies"),
            ("pages_allocated_total", "cumulative pool page allocations"),
            ("generated_tokens_total", "tokens generated by finished requests"),
        ):
            r.counter(name, help)
        for name, help in (
            ("queue_depth", "requests waiting in the admission queue"),
            ("decode_batch_occupancy", "slots in the current decode batch"),
            ("pages_free", "free-list pages"),
            ("pages_total", "usable pool pages (excl. null page)"),
            ("prefix_cache_pages", "pages pinned by the radix prefix index"),
            ("pages_index_pinned", "audit: pages held by the prefix index (deep)"),
            ("pages_slot_held", "audit: pages held by slots only (deep)"),
            ("jit_decode_traces", "compiled entries of the paged decode step"),
            ("jit_prefill_chunk_traces", "compiled entries of the chunk step"),
            ("jit_prefill_traces", "compiled entries of the one-shot prefill"),
        ):
            r.gauge(name, help)
        occ_edges = tuple(range(max_seqs + 1)) if max_seqs else _OCCUPANCY_EDGES_DEFAULT
        r.histogram("decode_batch_occupancy",
                    "decode batch size per engine step", edges=occ_edges)
        r.histogram("queue_steps", "admission wait per (re-)admission, in steps")
        r.histogram("ttft_steps", "arrival -> first token, in engine steps")
        r.histogram("generated_tokens", "tokens generated per finished request",
                    edges=(1, 2, 4, 8, 16, 32, 64, 128))
        self._counter_base: Dict[str, int] = {}

    # -- plumbing ------------------------------------------------------------

    def _sync_counter(self, name, cumulative) -> None:  # repro: hot-loop
        """Mirror an engine-side cumulative host int into a counter."""
        c = self.registry.counter(name)
        base = self._counter_base.get(name, 0)
        if cumulative > base:
            c.inc(cumulative - base)
            self._counter_base[name] = cumulative

    def timeline(self, req) -> RequestTimeline:  # repro: hot-loop
        tl = req.timeline
        self.timelines.setdefault(req.rid, tl)
        return tl

    def device_span(self, name: str):
        """Context manager for a jitted dispatch: a ``jax.profiler``
        TraceAnnotation when deep observability is on (so device traces
        align with the host spans), else a shared no-op context."""
        if not self.deep:
            return _NULL_CTX
        import jax.profiler

        return jax.profiler.TraceAnnotation(name)

    # -- request lifecycle events (called by the scheduler) ------------------

    def request_queued(self, req, arrival_step) -> None:  # repro: hot-loop
        now = self._clock()
        tl = self.timeline(req)
        tl.mark("arrival", arrival_step, now)
        tl.begin("queued", arrival_step, now)

    def request_admitted(self, req, step, cached_tokens, prompt_tokens) -> None:  # repro: hot-loop
        now = self._clock()
        tl = self.timeline(req)
        tl.cached_prompt_tokens = cached_tokens
        tl.mark("admitted", step, now)
        queued = tl.end("queued", step, now)
        tl.begin("prefill", step, now,
                 cached_tokens=cached_tokens, prompt_tokens=prompt_tokens)
        r = self.registry
        r.counter("admissions_total").inc()
        r.counter("prompt_tokens_total").inc(prompt_tokens)
        r.counter("prefix_cached_tokens_total").inc(cached_tokens)
        r.histogram("queue_steps").observe(step - queued.begin_step)

    def request_preempted(self, req, step) -> None:  # repro: hot-loop
        now = self._clock()
        tl = self.timeline(req)
        tl.n_preemptions += 1
        tl.close_all(step, now, preempted=True)
        tl.instant("preempt", step, now)
        tl.begin("queued", step, now, requeued=True)
        self.registry.counter("preemptions_total").inc()

    def request_finished(self, req, step) -> None:  # repro: hot-loop
        now = self._clock()
        tl = self.timeline(req)
        tl.mark("finish", step, now)
        tl.close_all(step, now)
        r = self.registry
        r.counter("finished_total").inc()
        r.counter("generated_tokens_total").inc(req.n_generated)
        r.histogram("generated_tokens").observe(req.n_generated)

    # -- engine events -------------------------------------------------------

    def chunk_begin(self, req, step, off, n) -> None:  # repro: hot-loop
        self.timeline(req).begin("prefill-chunk", step, self._clock(),
                                 off=off, tokens=n)

    def chunk_end(self, req, step) -> None:  # repro: hot-loop
        self.timeline(req).end("prefill-chunk", step, self._clock())

    def prefill_complete(self, req, step) -> None:  # repro: hot-loop
        """Final chunk (or one-shot prefill) done: the first token of this
        admission is sampled *now*, and the request joins the decode batch."""
        now = self._clock()
        tl = self.timeline(req)
        tl.end("prefill", step, now)
        if tl.mark("first_token", step, now):
            arrival = tl.marks["arrival"][0]
            self.registry.histogram("ttft_steps").observe(step - arrival)
        tl.begin("decode", step, now)

    def decode_batch(self, occupancy) -> None:  # repro: hot-loop
        self._last_decode_batch = occupancy
        r = self.registry
        r.gauge("decode_batch_occupancy").set(occupancy)
        r.histogram("decode_batch_occupancy").observe(occupancy)

    def step_begin(self) -> float:  # repro: hot-loop
        return self._clock()

    def step_end(self, engine, t0, audit=None) -> None:  # repro: hot-loop
        """Per-step bookkeeping at the step boundary (a sync point the
        engine already owns): cumulative counters, cheap gauges, the
        engine-step span, and — deep only — the audit-backed pool split."""
        now = self._clock()
        r = self.registry
        self._sync_counter("engine_steps_total", engine.step_count)
        self._sync_counter("decode_steps_total", engine.decode_steps)
        self._sync_counter("prefill_tokens_total", engine.prefill_tokens)
        self._sync_counter("prefill_chunks_total", engine.prefill_chunks)
        ps = engine.kv.pool_stats()
        self._sync_counter("prefix_pages_aliased_total", ps["pages_aliased_total"])
        self._sync_counter("cow_copies_total", ps["cow_copies_total"])
        self._sync_counter("pages_allocated_total", ps["pages_allocated_total"])
        queue_depth = len(engine.sched.queue)
        r.gauge("queue_depth").set(queue_depth)
        r.gauge("pages_free").set(ps["pages_free"])
        r.gauge("pages_total").set(ps["pages_total"])
        r.gauge("prefix_cache_pages").set(ps["prefix_cache_pages"])
        for gname, fn in (
            ("jit_decode_traces", engine._decode),
            ("jit_prefill_chunk_traces", engine._chunk_fn),
            ("jit_prefill_traces", engine._prefill),
        ):
            size = getattr(fn, "_cache_size", None)
            if size is not None:  # sanitizer tests wrap the jits
                r.gauge(gname).set(size())
        if audit is not None:
            r.gauge("pages_index_pinned").set(audit.index_pinned)
            r.gauge("pages_slot_held").set(audit.slot_held)
        step = engine.step_count - 1  # the step that just ran
        if len(self.step_spans) < self.max_step_spans:
            self.step_spans.append(Span(
                "engine-step", "engine", step, t0, step, now,
                {"step": step, "decode_batch": self._last_decode_batch,
                 "queue_depth": queue_depth, "pages_free": ps["pages_free"]},
            ))

    # -- Chrome-trace / Perfetto export --------------------------------------

    _PID = 1

    def chrome_trace(self) -> Dict[str, Any]:
        """The recorded spans as a Chrome-trace JSON object (Perfetto- and
        ``chrome://tracing``-loadable): tid 0 is the engine-step track plus
        occupancy/queue/pool counter tracks; each request gets its own tid
        with phase spans and preemption instants.  Still-open spans (live
        engines) export with ``"open": true`` and a to-now duration."""
        now = self._clock()
        pid = self._PID

        def ts(t: float) -> float:
            return (t - self.t0) * 1e6  # Chrome trace wants microseconds

        events: List[Dict[str, Any]] = [
            {"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
             "args": {"name": "repro.serve"}},
            {"ph": "M", "pid": pid, "tid": 0, "name": "thread_name",
             "args": {"name": "engine steps"}},
        ]
        for span in self.step_spans:
            events.append({
                "ph": "X", "pid": pid, "tid": 0, "name": span.name,
                "cat": span.cat, "ts": ts(span.t_begin),
                "dur": max(0.0, (span.t_end - span.t_begin) * 1e6),
                "args": dict(span.attrs),
            })
            for counter in ("decode_batch", "queue_depth", "pages_free"):
                events.append({
                    "ph": "C", "pid": pid, "tid": 0, "name": counter,
                    "ts": ts(span.t_begin),
                    "args": {counter: span.attrs.get(counter, 0)},
                })
        for tid_i, (rid, tl) in enumerate(self.timelines.items(), start=1):
            events.append({"ph": "M", "pid": pid, "tid": tid_i,
                           "name": "thread_name",
                           "args": {"name": f"request {rid}"}})
            for span in tl.spans:
                t_end = span.t_end if not span.open else now
                args = {"rid": rid, "begin_step": span.begin_step,
                        "end_step": span.end_step, **span.attrs}
                if span.open:
                    args["open"] = True
                events.append({
                    "ph": "X", "pid": pid, "tid": tid_i, "name": span.name,
                    "cat": span.cat, "ts": ts(span.t_begin),
                    "dur": max(0.0, (t_end - span.t_begin) * 1e6),
                    "args": args,
                })
            for name, step, t, attrs in tl.instants:
                events.append({
                    "ph": "i", "pid": pid, "tid": tid_i, "name": name,
                    "s": "t", "ts": ts(t),
                    "args": {"rid": rid, "step": step, **attrs},
                })
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"source": "repro.serve.obs",
                          "metrics": self.registry.snapshot()},
        }

    def export_chrome_trace(self, path: str) -> Dict[str, Any]:
        trace = self.chrome_trace()
        with open(path, "w", encoding="utf-8") as f:
            json.dump(trace, f)
        return trace


# --------------------------------------------------------------------------
# Report builder (launch driver / JSON report)
# --------------------------------------------------------------------------


def _finite(x: float) -> Optional[float]:
    """inf/nan -> None: the JSON report must be standard-parseable."""
    return x if x == x and abs(x) != float("inf") else None


def build_serve_report(engine, done, wall_s: Optional[float] = None,
                       useful_tokens: Optional[int] = None) -> Dict[str, Any]:
    """Machine-readable serving report, built from the metrics registry and
    the span-derived request stats — the single source the human table in
    ``repro.launch.serve`` prints from (no print-side arithmetic)."""
    kv = engine.kv
    requests = []
    for r in done:
        s = r.stats
        requests.append({
            "rid": r.rid,
            "arrival_step": s.arrival_step,
            "admitted_step": s.admitted_step,
            "queue_steps": s.queue_steps,
            "ttft_steps": s.ttft_steps,
            "ttft_ms": _finite(s.ttft_s * 1e3),
            "preemptions": s.n_preemptions,
            "cached_prompt_tokens": s.cached_prompt_tokens,
            "decode_tok_s": _finite(s.decode_tok_s(len(r.out_tokens))),
            "n_tokens": len(r.out_tokens),
        })
    prompt_tokens = sum(r.prompt_len for r in done)
    cached = sum(r.stats.cached_prompt_tokens for r in done)
    sharing_mode = None
    if kv.sharing:
        sharing_mode = "compute-skipping" if kv.skip_prefill else "memory-dedup"
    report = {
        "engine": {
            "steps": engine.step_count,
            "decode_steps": engine.decode_steps,
            "prefill_tokens": engine.prefill_tokens,
            "prefill_chunks": engine.prefill_chunks,
            "max_seqs": engine.ec.max_seqs,
            "chunked_prefill": engine.ec.chunked_prefill,
            "chunk_size": engine.chunk_size,
            "prefill_tokens_per_step": engine.tokens_per_step,
        },
        "pool": {
            **kv.pool_stats(),
            "page_size": kv.page_size,
            "cache_mb": kv.cache_bytes() / 1e6,
        },
        "prefix_cache": {
            "enabled": kv.sharing,
            "mode": sharing_mode,
            "cached_prompt_tokens": cached,
            "prompt_tokens": prompt_tokens,
            "hit_rate": cached / prompt_tokens if prompt_tokens else 0.0,
        },
        "requests": requests,
        "metrics": engine.obs.registry.snapshot(),
    }
    if wall_s is not None:
        report["workload"] = {
            "num_requests": len(done),
            "useful_tokens": useful_tokens,
            "wall_s": wall_s,
            "tok_s": _finite(useful_tokens / wall_s)
            if useful_tokens is not None and wall_s > 0 else None,
        }
    return report


# --------------------------------------------------------------------------
# Chrome-trace validation (CI gate on the exported file)
# --------------------------------------------------------------------------

_X_REQUIRED = ("name", "cat", "ts", "dur", "pid", "tid")


def validate_chrome_trace(obj, require_request_track: bool = True) -> List[str]:
    """Schema-check a Chrome-trace JSON object; returns problem strings
    (empty list = valid).  Checks the trace-event contract Perfetto relies
    on (typed ``ph``, complete events with non-negative ``ts``/``dur``)
    plus the repo's own: a non-empty engine-step track and — unless
    ``require_request_track=False`` — at least one request span track."""
    problems: List[str] = []
    if not isinstance(obj, dict):
        return [f"top level must be an object, got {type(obj).__name__}"]
    events = obj.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["traceEvents must be a non-empty array"]
    cats = {"engine": 0, "request": 0}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if not isinstance(ph, str) or not ph:
            problems.append(f"event {i}: missing phase 'ph'")
            continue
        if ph == "X":
            missing = [k for k in _X_REQUIRED if k not in ev]
            if missing:
                problems.append(f"event {i}: X event missing {missing}")
                continue
            if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
                problems.append(f"event {i}: bad ts {ev['ts']!r}")
            if not isinstance(ev["dur"], (int, float)) or ev["dur"] < 0:
                problems.append(f"event {i}: bad dur {ev['dur']!r}")
            cat = ev.get("cat")
            if cat in cats:
                cats[cat] += 1
    if cats["engine"] == 0:
        problems.append("no engine-step track (zero X events with cat='engine')")
    if require_request_track and cats["request"] == 0:
        problems.append("no request span track (zero X events with cat='request')")
    return problems


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="validate Chrome-trace JSON emitted by repro.serve.obs"
    )
    ap.add_argument("paths", nargs="+", help="trace JSON file(s) to validate")
    ap.add_argument("--allow-empty-requests", action="store_true",
                    help="don't require a request span track")
    args = ap.parse_args(argv)
    rc = 0
    for path in args.paths:
        try:
            with open(path, encoding="utf-8") as f:
                obj = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: unreadable trace: {e}")
            rc = 1
            continue
        problems = validate_chrome_trace(
            obj, require_request_track=not args.allow_empty_requests
        )
        if problems:
            for p in problems:
                print(f"{path}: {p}")
            rc = 1
        else:
            n = len(obj["traceEvents"])
            print(f"{path}: valid chrome trace ({n} events)")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())

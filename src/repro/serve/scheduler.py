"""Continuous-batching scheduler: queue, admission, preemption, slot re-fill.

The scheduler owns request lifecycle and the mapping requests -> batch slots;
the :class:`~repro.serve.kvcache.PagedKVCache` owns pages.  Policy:

* **FIFO admission** with head-of-line blocking: requests are admitted in
  arrival order, each only when a batch slot is free AND the free-page
  budget covers its prompt plus one decode page (no over-subscription at
  admit time; growth is on-demand).
* **On-demand growth**: before every decode step each running slot's page
  table is extended to cover the token about to be written.
* **LIFO preemption**: when growth hits an empty pool, the most recently
  admitted request is preempted — its pages are released and it re-enters
  the *front* of the queue with its generated tokens kept, to be recomputed
  (prompt + generated so far are re-prefilled on re-admission).
* **Slot re-fill**: a finished or preempted request frees its slot the same
  step; the next admission can land in it immediately.

Request lifecycle is recorded as spans/milestones on each request's
:class:`~repro.serve.obs.RequestTimeline` at these scheduling events, and
``Request.stats`` (queue steps, TTFT, decode tok/s) is a derived
:class:`~repro.serve.obs.RequestStats` view over that single record — the
launch driver and benchmarks report latency without instrumenting the
engine.  Wall times are recorded at bookkeeping time: with the engine's
deferred host sync the device may still be draining enqueued steps, so
per-request ``decode_tok_s`` measures enqueue rate; workload-level
tokens/s (useful tokens / engine wall) is the throughput headline.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serve.obs import (  # noqa: F401  (RequestStats re-exported)
    Observability,
    RequestStats,
    RequestTimeline,
)


@dataclasses.dataclass
class Request:
    """One generation request flowing through the engine."""

    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    arrival_step: int = 0
    # per-request modality inputs beyond the token prompt (e.g. enc-dec
    # audio_embeds).  Kept on the request so preemption-with-recompute can
    # re-run the admission-time installs (encoder pass) on re-admission.
    extras: Optional[Dict] = None
    state: str = "pending"  # pending | waiting | running | finished
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    # tokens generated on-device but not yet copied to out_tokens: the
    # engine defers host syncs between scheduling events, so length
    # bookkeeping must count them (values arrive at the next flush)
    n_pending: int = 0
    # chunked-prefill progress: [0, prefill_target) while the prompt is
    # being fed through the cache page by page; the request joins the
    # decode batch only once the whole (effective) prompt is in.  Reset on
    # preemption — a re-admitted request re-prefills from scratch.
    prefill_pos: int = 0
    prefill_target: int = 0
    # span/milestone record written at scheduling events; ``stats`` below is
    # the derived numeric view (step AND wall TTFT from the same milestones)
    timeline: RequestTimeline = dataclasses.field(default_factory=RequestTimeline)

    @property
    def stats(self) -> RequestStats:
        return RequestStats(self.timeline)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def n_generated(self) -> int:
        return len(self.out_tokens) + self.n_pending

    @property
    def effective_prompt(self) -> np.ndarray:
        """Prompt for (re-)prefill: original + tokens generated pre-preemption."""
        assert self.n_pending == 0, "flush pending tokens before re-prefill"
        if not self.out_tokens:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.out_tokens, np.int32)]
        )

    @property
    def prefilling(self) -> bool:
        """Admitted but the prompt is not fully through the cache yet."""
        return self.state == "running" and self.prefill_pos < self.prefill_target

    @property
    def next_pos(self) -> int:
        """Absolute position the next decode step writes for this request
        (the last generated token's position)."""
        return self.prompt_len + self.n_generated - 1

    @property
    def done(self) -> bool:
        return self.n_generated >= self.max_new_tokens

    def total_len(self) -> int:
        return self.prompt_len + self.max_new_tokens


class Scheduler:
    """Drives request state against the paged cache's page budget."""

    def __init__(self, kv, max_seqs: int, obs: Optional[Observability] = None):
        self.kv = kv
        self.max_seqs = max_seqs
        # lifecycle events are recorded here; a standalone scheduler gets a
        # private lightweight recorder, the Engine passes its own
        self.obs = obs if obs is not None else Observability(max_seqs=max_seqs)
        self.pending: List[Request] = []  # not yet arrived (simulated clock)
        self.queue: Deque[Request] = collections.deque()
        self.slots: List[Optional[Request]] = [None] * max_seqs
        self._admit_order: List[int] = []  # slots by admission recency
        self.finished: Dict[int, Request] = {}

    # -- intake -------------------------------------------------------------

    def submit(self, req: Request) -> None:
        known = (
            {r.rid for r in self.pending}
            | {r.rid for r in self.queue}
            | {r.rid for r in self.slots if r is not None}
            | set(self.finished)
        )
        if req.rid in known:
            raise ValueError(f"duplicate request id {req.rid}")
        if not self.kv.fits(req.total_len()):
            raise ValueError(
                f"request {req.rid}: {req.total_len()} tokens can never fit "
                f"(max_len {self.kv.max_len}, pool "
                f"{self.kv.allocator.num_pages - 1} pages)"
            )
        self.pending.append(req)
        self.pending.sort(key=lambda r: (r.arrival_step, r.rid))

    def poll_arrivals(self, step: int) -> None:  # repro: hot-loop
        """Move requests whose simulated arrival step has come into the queue."""
        while self.pending and self.pending[0].arrival_step <= step:
            req = self.pending.pop(0)
            req.state = "waiting"
            self.obs.request_queued(req, req.arrival_step)
            self.queue.append(req)

    # -- admission ----------------------------------------------------------

    def admit(self, step: int) -> List[Tuple[int, Request]]:
        """Admit queue-head requests while slots and pages allow (FIFO)."""
        admitted = []
        while self.queue:
            free = [i for i, r in enumerate(self.slots) if r is None]
            if not free:
                break
            req = self.queue[0]
            if not self.kv.can_admit(req.effective_prompt):
                break  # head-of-line blocks: preserves FIFO fairness
            self.queue.popleft()
            slot = free[0]
            matched = self.kv.admit(slot, req.effective_prompt)
            assert matched is not None, "can_admit passed but admit failed"
            self.slots[slot] = req
            self._admit_order.append(slot)
            req.state = "running"
            req.prefill_target = len(req.effective_prompt)
            # shared-prefix admission: when the family supports compute
            # skipping, prefill resumes at the first uncached page boundary
            # (capped one short of the whole prompt — the final chunk must
            # still run to produce the first token's logits; its write is
            # null-routed by the cache when the position is aliased).
            # Memory-dedup-only families (MoE stacks) alias pages but
            # recompute every token, so they restart at 0.
            req.prefill_pos = (
                min(matched, req.prefill_target - 1)
                if self.kv.skip_prefill else 0
            )
            self.obs.request_admitted(req, step, matched, req.prefill_target)
            admitted.append((slot, req))
        return admitted

    # -- growth / preemption ------------------------------------------------

    def grow_for_decode(self, step: int) -> List[Request]:
        """Ensure every decoding slot can write its next token *privately*;
        preempt LIFO on OOM.  Returns the requests preempted this step.
        Private means mapped AND exclusively owned: a target page shared
        with the prefix index or another slot copies-on-write here
        (:meth:`PagedKVCache.prepare_decode_write`), and a COW allocation
        failure preempts exactly like a growth failure.  Mid-prefill slots
        need no growth (admission reserved their prompt + one decode page)
        but remain preemption victims like any other slot."""
        preempted: List[Request] = []
        for slot in list(self._admit_order):  # oldest first get pages first
            req = self.slots[slot]
            if req is None or req.prefilling:
                continue
            while not (
                self.kv.ensure_capacity(slot, req.next_pos)
                and self.kv.prepare_decode_write(slot, req.next_pos)
            ):
                victim_slot = self._admit_order[-1]  # youngest
                victim = self.preempt(victim_slot, step)
                preempted.append(victim)
                if victim_slot == slot:
                    break  # the needy slot preempted itself
        return preempted

    def preempt(self, slot: int, step: int) -> Request:
        req = self.slots[slot]
        assert req is not None
        self.kv.release(slot)
        self.slots[slot] = None
        self._admit_order.remove(slot)
        req.state = "waiting"
        # re-admission re-prefills (recompute discipline) — though pages the
        # preempted prefill already published to the prefix index let the
        # next admission resume at the first uncached page boundary
        req.prefill_pos = 0
        self.obs.request_preempted(req, step)
        self.queue.appendleft(req)  # preempted requests resume first
        return req

    # -- completion ---------------------------------------------------------

    def finish(self, slot: int, step: int) -> Request:
        req = self.slots[slot]
        assert req is not None
        self.kv.release(slot)
        self.slots[slot] = None
        self._admit_order.remove(slot)
        req.state = "finished"
        self.obs.request_finished(req, step)
        self.finished[req.rid] = req
        return req

    # -- queries ------------------------------------------------------------

    @property
    def running(self) -> List[Tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self.slots) if r is not None]

    @property
    def decoding(self) -> List[Tuple[int, Request]]:
        """Occupied slots whose prompt is fully in — the decode batch."""
        return [(i, r) for i, r in self.running if not r.prefilling]

    @property
    def prefilling(self) -> List[Tuple[int, Request]]:
        """Occupied slots mid-prefill, oldest admission first (the order
        the chunk budget is spent in — FIFO toward first token)."""
        return [
            (s, self.slots[s]) for s in self._admit_order
            if self.slots[s] is not None and self.slots[s].prefilling
        ]

    def has_work(self) -> bool:
        return bool(self.pending or self.queue or any(
            r is not None for r in self.slots
        ))

"""Continuous-batching scheduler: queue, admission, preemption, slot re-fill.

The scheduler owns request lifecycle and the mapping requests -> batch slots;
the :class:`~repro.serve.kvcache.PagedKVCache` owns pages.  Policy:

* **FIFO admission** with head-of-line blocking: requests are admitted in
  arrival order, each only when a batch slot is free AND the free-page
  budget covers its prompt plus one decode page (no over-subscription at
  admit time; growth is on-demand).
* **On-demand growth**: before every decode step each running slot's page
  table is extended to cover the token about to be written.
* **LIFO preemption**: when growth hits an empty pool, the most recently
  admitted request is preempted — its pages are released and it re-enters
  the *front* of the queue with its generated tokens kept, to be recomputed
  (prompt + generated so far are re-prefilled on re-admission).
* **Slot re-fill**: a finished or preempted request frees its slot the same
  step; the next admission can land in it immediately.

Per-request stats (queue steps, TTFT, decode tok/s) accumulate on the
:class:`Request` so the launch driver and benchmarks can report latency
percentiles without instrumenting the engine.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class RequestStats:
    """Step- and wall-clock timings for one request.

    Wall times are recorded at bookkeeping time: with the engine's deferred
    host sync the device may still be draining enqueued steps, so per-request
    ``decode_tok_s`` measures enqueue rate; workload-level tokens/s (useful
    tokens / engine wall) is the throughput headline.
    """

    arrival_step: int = 0
    admitted_step: int = -1
    first_token_step: int = -1
    finish_step: int = -1
    t_arrival: float = 0.0
    t_admitted: float = 0.0
    t_first_token: float = 0.0
    t_finish: float = 0.0
    n_preemptions: int = 0
    # prompt tokens served from the shared prefix cache at the latest
    # admission (page-aliased instead of recomputed-and-stored); feeds the
    # launch driver's per-run prefix hit-rate line
    cached_prompt_tokens: int = 0

    @property
    def queue_steps(self) -> int:
        return self.admitted_step - self.arrival_step

    @property
    def ttft_s(self) -> float:
        return self.t_first_token - self.t_arrival

    def decode_tok_s(self, n_generated: int) -> float:
        dt = self.t_finish - self.t_first_token
        return (n_generated - 1) / dt if dt > 0 and n_generated > 1 else float("inf")


@dataclasses.dataclass
class Request:
    """One generation request flowing through the engine."""

    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    arrival_step: int = 0
    # per-request modality inputs beyond the token prompt (e.g. enc-dec
    # audio_embeds).  Kept on the request so preemption-with-recompute can
    # re-run the admission-time installs (encoder pass) on re-admission.
    extras: Optional[Dict] = None
    state: str = "pending"  # pending | waiting | running | finished
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    # tokens generated on-device but not yet copied to out_tokens: the
    # engine defers host syncs between scheduling events, so length
    # bookkeeping must count them (values arrive at the next flush)
    n_pending: int = 0
    # chunked-prefill progress: [0, prefill_target) while the prompt is
    # being fed through the cache page by page; the request joins the
    # decode batch only once the whole (effective) prompt is in.  Reset on
    # preemption — a re-admitted request re-prefills from scratch.
    prefill_pos: int = 0
    prefill_target: int = 0
    stats: RequestStats = dataclasses.field(default_factory=RequestStats)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def n_generated(self) -> int:
        return len(self.out_tokens) + self.n_pending

    @property
    def effective_prompt(self) -> np.ndarray:
        """Prompt for (re-)prefill: original + tokens generated pre-preemption."""
        assert self.n_pending == 0, "flush pending tokens before re-prefill"
        if not self.out_tokens:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.out_tokens, np.int32)]
        )

    @property
    def prefilling(self) -> bool:
        """Admitted but the prompt is not fully through the cache yet."""
        return self.state == "running" and self.prefill_pos < self.prefill_target

    @property
    def next_pos(self) -> int:
        """Absolute position the next decode step writes for this request
        (the last generated token's position)."""
        return self.prompt_len + self.n_generated - 1

    @property
    def done(self) -> bool:
        return self.n_generated >= self.max_new_tokens

    def total_len(self) -> int:
        return self.prompt_len + self.max_new_tokens


class Scheduler:
    """Drives request state against the paged cache's page budget."""

    def __init__(self, kv, max_seqs: int):
        self.kv = kv
        self.max_seqs = max_seqs
        self.pending: List[Request] = []  # not yet arrived (simulated clock)
        self.queue: Deque[Request] = collections.deque()
        self.slots: List[Optional[Request]] = [None] * max_seqs
        self._admit_order: List[int] = []  # slots by admission recency
        self.finished: Dict[int, Request] = {}

    # -- intake -------------------------------------------------------------

    def submit(self, req: Request) -> None:
        known = (
            {r.rid for r in self.pending}
            | {r.rid for r in self.queue}
            | {r.rid for r in self.slots if r is not None}
            | set(self.finished)
        )
        if req.rid in known:
            raise ValueError(f"duplicate request id {req.rid}")
        if not self.kv.fits(req.total_len()):
            raise ValueError(
                f"request {req.rid}: {req.total_len()} tokens can never fit "
                f"(max_len {self.kv.max_len}, pool "
                f"{self.kv.allocator.num_pages - 1} pages)"
            )
        self.pending.append(req)
        self.pending.sort(key=lambda r: (r.arrival_step, r.rid))

    def poll_arrivals(self, step: int) -> None:
        """Move requests whose simulated arrival step has come into the queue."""
        now = time.perf_counter()
        while self.pending and self.pending[0].arrival_step <= step:
            req = self.pending.pop(0)
            req.state = "waiting"
            req.stats.arrival_step = req.arrival_step
            req.stats.t_arrival = now
            self.queue.append(req)

    # -- admission ----------------------------------------------------------

    def admit(self, step: int) -> List[Tuple[int, Request]]:
        """Admit queue-head requests while slots and pages allow (FIFO)."""
        admitted = []
        while self.queue:
            free = [i for i, r in enumerate(self.slots) if r is None]
            if not free:
                break
            req = self.queue[0]
            if not self.kv.can_admit(req.effective_prompt):
                break  # head-of-line blocks: preserves FIFO fairness
            self.queue.popleft()
            slot = free[0]
            matched = self.kv.admit(slot, req.effective_prompt)
            assert matched is not None, "can_admit passed but admit failed"
            self.slots[slot] = req
            self._admit_order.append(slot)
            req.state = "running"
            req.prefill_target = len(req.effective_prompt)
            # shared-prefix admission: when the family supports compute
            # skipping, prefill resumes at the first uncached page boundary
            # (capped one short of the whole prompt — the final chunk must
            # still run to produce the first token's logits; its write is
            # null-routed by the cache when the position is aliased).
            # Memory-dedup-only families (MoE stacks) alias pages but
            # recompute every token, so they restart at 0.
            req.prefill_pos = (
                min(matched, req.prefill_target - 1)
                if self.kv.skip_prefill else 0
            )
            req.stats.cached_prompt_tokens = matched
            now = time.perf_counter()
            if req.stats.admitted_step < 0:
                req.stats.admitted_step = step
                req.stats.t_admitted = now
            admitted.append((slot, req))
        return admitted

    # -- growth / preemption ------------------------------------------------

    def grow_for_decode(self, step: int) -> List[Request]:
        """Ensure every decoding slot can write its next token *privately*;
        preempt LIFO on OOM.  Returns the requests preempted this step.
        Private means mapped AND exclusively owned: a target page shared
        with the prefix index or another slot copies-on-write here
        (:meth:`PagedKVCache.prepare_decode_write`), and a COW allocation
        failure preempts exactly like a growth failure.  Mid-prefill slots
        need no growth (admission reserved their prompt + one decode page)
        but remain preemption victims like any other slot."""
        preempted: List[Request] = []
        for slot in list(self._admit_order):  # oldest first get pages first
            req = self.slots[slot]
            if req is None or req.prefilling:
                continue
            while not (
                self.kv.ensure_capacity(slot, req.next_pos)
                and self.kv.prepare_decode_write(slot, req.next_pos)
            ):
                victim_slot = self._admit_order[-1]  # youngest
                victim = self.preempt(victim_slot, step)
                preempted.append(victim)
                if victim_slot == slot:
                    break  # the needy slot preempted itself
        return preempted

    def preempt(self, slot: int, step: int) -> Request:
        req = self.slots[slot]
        assert req is not None
        self.kv.release(slot)
        self.slots[slot] = None
        self._admit_order.remove(slot)
        req.state = "waiting"
        # re-admission re-prefills (recompute discipline) — though pages the
        # preempted prefill already published to the prefix index let the
        # next admission resume at the first uncached page boundary
        req.prefill_pos = 0
        req.stats.n_preemptions += 1
        self.queue.appendleft(req)  # preempted requests resume first
        return req

    # -- completion ---------------------------------------------------------

    def finish(self, slot: int, step: int) -> Request:
        req = self.slots[slot]
        assert req is not None
        self.kv.release(slot)
        self.slots[slot] = None
        self._admit_order.remove(slot)
        req.state = "finished"
        req.stats.finish_step = step
        req.stats.t_finish = time.perf_counter()
        self.finished[req.rid] = req
        return req

    # -- queries ------------------------------------------------------------

    @property
    def running(self) -> List[Tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self.slots) if r is not None]

    @property
    def decoding(self) -> List[Tuple[int, Request]]:
        """Occupied slots whose prompt is fully in — the decode batch."""
        return [(i, r) for i, r in self.running if not r.prefilling]

    @property
    def prefilling(self) -> List[Tuple[int, Request]]:
        """Occupied slots mid-prefill, oldest admission first (the order
        the chunk budget is spent in — FIFO toward first token)."""
        return [
            (s, self.slots[s]) for s in self._admit_order
            if self.slots[s] is not None and self.slots[s].prefilling
        ]

    def has_work(self) -> bool:
        return bool(self.pending or self.queue or any(
            r is not None for r in self.slots
        ))

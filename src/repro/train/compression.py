"""Gradient compression for the DP all-reduce.

int8 quantization with a per-leaf fp32 scale: gradients cross the (slow,
cross-pod DCN) data-parallel links as 8-bit integers instead of 32/16-bit
floats — 2-4x less wire traffic where it matters most (the "pod" axis).

Scheme (error-feedback-free, stateless):
    scale = max|g| / 127          (per leaf, psum-maxed so all shards agree)
    q     = round(g / scale)  in int8
    ḡ     = psum(q) * scale / n   (accumulate in int32: safe to 2^23 shards)

Used inside shard_map over the DP axes by the trainer when
``grad_compression="int8"``; with GSPMD handling TP, only the DP reduction is
made explicit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_leaf(g: jnp.ndarray):
    gf = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_leaf(q: jnp.ndarray, scale: jnp.ndarray, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(grads, axis_names):
    """psum a grad pytree over ``axis_names`` with int8 wire format."""

    def one(g):
        q, scale = quantize_leaf(g)
        # all shards must agree on the scale -> max-reduce it first (tiny)
        scale = jax.lax.pmax(scale, axis_names)
        q, _ = quantize_leaf(g)  # requantize with local scale ~= shared scale
        q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127)
        total = jax.lax.psum(q.astype(jnp.int32), axis_names)
        n = jax.lax.psum(jnp.ones((), jnp.int32), axis_names)
        return (total.astype(jnp.float32) * scale / n).astype(g.dtype)

    return jax.tree.map(one, grads)

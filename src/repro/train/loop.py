"""Training loop with the fault-tolerance machinery for 1000+-node runs.

Features:

* **microbatching** — gradient accumulation over ``accum_steps`` via
  ``lax.scan`` inside the jitted step (global batch stays constant while the
  per-device live batch shrinks);
* **checkpoint/restart** — async atomic snapshots (repro.checkpoint); on
  start, the trainer resumes from the latest step automatically;
* **elastic restarts** — restore reshards onto whatever mesh the restarted
  job has (mesh is an argument, checkpoints are mesh-independent);
* **straggler mitigation** — a per-step deadline; steps that exceed it are
  recorded and a (pluggable) policy reacts: log, checkpoint-now, or abort to
  trigger the cluster-level restart. On real TPU fleets the actual detection
  signal is the per-host barrier wait, which this wall-clock deadline stands
  in for;
* **data determinism** — batch at step N depends only on (seed, N): replays
  after restart are bit-identical, stragglers/failures never skew the stream;
* **grad compression** — optional int8 wire format for the DP reduction
  (repro.train.compression), applied via an explicit shard_map psum when
  enabled.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.distributed import axes as AX
from repro.distributed import sharding as SH
from repro.models import model as M
from repro.optim import OptConfig, adamw_init, adamw_update, cosine_schedule


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    accum_steps: int = 1
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_last_k: int = 3
    step_deadline_s: Optional[float] = None  # straggler watchdog
    log_every: int = 10
    grad_compression: Optional[str] = None  # None | "int8"
    seed: int = 0


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        mesh,
        tc: TrainerConfig,
        oc: Optional[OptConfig] = None,
        lr_fn: Optional[Callable] = None,
    ):
        oc = oc or OptConfig()
        self.cfg, self.mesh, self.tc, self.oc = cfg, mesh, tc, oc
        self.lr_fn = lr_fn or cosine_schedule(oc.lr, 10, tc.steps)
        self.ckpt = (
            CheckpointManager(tc.checkpoint_dir, tc.keep_last_k)
            if tc.checkpoint_dir else None
        )
        self.straggler_events: List[Dict] = []
        self._build()

    # ------------------------------------------------------------------

    def _build(self):
        cfg, mesh = self.cfg, self.mesh
        params_shape = jax.eval_shape(
            lambda: M.init_params(cfg, jax.random.PRNGKey(self.tc.seed))
        )
        self.pspecs = SH.param_pspecs(cfg, mesh, params_shape)
        self.p_shard = SH.named(mesh, self.pspecs)
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        self.ospecs = SH.opt_pspecs(cfg, mesh, opt_shape, self.pspecs)
        self.o_shard = SH.named(mesh, self.ospecs)

        oc, lr_fn, tc = self.oc, self.lr_fn, self.tc

        def loss_over_microbatches(params, batch):
            if tc.accum_steps == 1:
                return M.loss_fn(cfg, params, batch)

            def split(x):
                b = x.shape[0] // tc.accum_steps if x.ndim and x.shape[0] else 0
                return x.reshape((tc.accum_steps, b) + x.shape[1:])

            mb = {}
            for k, v in batch.items():
                if k == "positions3":
                    mb[k] = jnp.moveaxis(
                        v.reshape(3, tc.accum_steps, -1, v.shape[-1]), 1, 0
                    )
                else:
                    mb[k] = split(v)

            def body(acc, one):
                lv, met = M.loss_fn(cfg, params, one)
                return acc + lv / tc.accum_steps, met

            total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), mb)
            return total, {"ce": total, "aux": jnp.zeros((), jnp.float32)}

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_over_microbatches(p, batch), has_aux=True
            )(params)
            if tc.grad_compression == "int8":
                from repro.train.compression import quantize_leaf, dequantize_leaf
                # stateless int8 round-trip on the already-psummed grads:
                # models wire-format error; the explicit shard_map variant is
                # exercised in tests (GSPMD owns the reduction here).
                grads = jax.tree.map(
                    lambda g: dequantize_leaf(*quantize_leaf(g), g.dtype), grads
                )
            lr_now = lr_fn(opt_state["step"])
            params, opt_state = adamw_update(grads, opt_state, params, oc, lr_now)
            return params, opt_state, {"loss": loss, "lr": lr_now, **metrics}

        with mesh, AX.policy(mesh):
            self.step_fn = jax.jit(
                train_step,
                in_shardings=(self.p_shard, self.o_shard, None),
                out_shardings=(self.p_shard, self.o_shard, None),
                donate_argnums=(0, 1),
            )

    # ------------------------------------------------------------------

    def init_state(self):
        cfg, mesh = self.cfg, self.mesh
        with mesh, AX.policy(mesh):
            params = jax.jit(
                lambda: M.init_params(cfg, jax.random.PRNGKey(self.tc.seed)),
                out_shardings=self.p_shard,
            )()
            opt = jax.jit(adamw_init, out_shardings=self.o_shard)(params)
        return params, opt

    def restore_or_init(self):
        if self.ckpt and self.ckpt.latest_step() is not None:
            params, opt = self.init_state()
            step, (params, opt) = self.ckpt.restore(
                (params, opt), shardings=(self.p_shard, self.o_shard)
            )
            return step, params, opt
        params, opt = self.init_state()
        return 0, params, opt

    # ------------------------------------------------------------------

    def fit(self, data, *, start_step: Optional[int] = None):
        step0, params, opt = self.restore_or_init()
        if start_step is not None:
            step0 = start_step
        history = []
        for step in range(step0, self.tc.steps):
            batch = data.batch(step)
            t0 = time.time()
            params, opt, metrics = self.step_fn(params, opt, batch)
            loss = float(metrics["loss"])  # sync point (also the step barrier)
            dt = time.time() - t0
            if self.tc.step_deadline_s and dt > self.tc.step_deadline_s:
                self.straggler_events.append(
                    {"step": step, "seconds": dt, "action": "logged"}
                )
            if step % self.tc.log_every == 0:
                history.append({"step": step, "loss": loss, "s": dt})
                print(f"step {step:6d} loss {loss:.4f} ({dt:.2f}s)", flush=True)  # repro: noqa RPR005 -- training progress log
            if (
                self.ckpt
                and self.tc.checkpoint_every
                and step > 0
                and step % self.tc.checkpoint_every == 0
            ):
                self.ckpt.save(step, (params, opt))
        if self.ckpt:
            self.ckpt.save(self.tc.steps, (params, opt), blocking=True)
        return params, opt, history

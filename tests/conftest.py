import os

# Tests see the single real CPU device (the 512-device forcing is ONLY for
# launch/dryrun.py).  Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)

import os

# Tests see the single real CPU device (the 512-device forcing is ONLY for
# launch/dryrun.py).  Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

jax.config.update("jax_enable_x64", False)


def pytest_addoption(parser):
    parser.addoption(
        "--sanitize",
        action="store_true",
        default=False,
        help=(
            "enable the runtime sanitizer harness (tests marked `sanitize`: "
            "transfer-guarded engine steps, tracer-leak checks, retrace "
            "budgets, per-step KV refcount audits)"
        ),
    )


@pytest.fixture
def sanitize_enabled(request):
    return request.config.getoption("--sanitize")


class RetraceBudget:
    """Assert jitted callables stay within a declared compile-count budget.

    Register each jitted function with :meth:`track`; teardown (or an
    explicit :meth:`verify`) reads ``_cache_size()`` and fails the test if
    any callable traced more entries than budgeted — the repo's guard
    against retrace churn (lint-side twin: staticcheck rule RPR003).
    """

    def __init__(self):
        self._entries = []

    def track(self, jitted, budget, label=""):
        assert hasattr(jitted, "_cache_size"), (
            f"{label or jitted}: not a jitted callable (no _cache_size)"
        )
        self._entries.append((jitted, budget, label))

    def verify(self):
        for fn, budget, label in self._entries:
            n = fn._cache_size()
            assert n <= budget, (
                f"retrace budget exceeded{f' ({label})' if label else ''}: "
                f"{n} compiled entries > budget {budget}"
            )


@pytest.fixture
def retrace_budget():
    tracker = RetraceBudget()
    yield tracker
    tracker.verify()

"""Sanity invariants of the analytic roofline calculator."""
import pytest

import repro.configs as C
from repro.analysis.analytic import (
    MeshInfo,
    cache_bytes_global,
    roofline_terms,
    step_flops_global,
)


@pytest.mark.parametrize("arch", C.arch_ids())
@pytest.mark.parametrize("shape", ["train_4k", "prefill_32k", "decode_32k"])
def test_terms_positive_and_finite(arch, shape):
    cfg = C.get_config(arch)
    t = roofline_terms(cfg, shape, MeshInfo.single(), accum=2)
    for k in ("compute", "memory", "collective"):
        assert t[k] >= 0 and t[k] < 1e5, (arch, shape, k, t[k])
    assert 0 <= t["roofline_fraction"] <= 1.0 + 1e-9
    assert t["roofline_fraction_serial"] <= t["roofline_fraction"] + 1e-9


def test_train_flops_close_to_6nd():
    """Matmul FLOPs should bracket 6·N_active·D (attention/logits extra)."""
    for arch in ("qwen1.5-110b", "minicpm-2b", "starcoder2-7b"):
        cfg = C.get_config(arch)
        f = step_flops_global(cfg, "train_4k")
        six_nd = 6 * cfg.active_param_count() * 4096 * 256
        assert 0.8 * six_nd < f < 2.5 * six_nd, (arch, f / six_nd)


def test_moe_flops_use_active_params():
    ds = C.get_config("deepseek-v3-671b")
    f = step_flops_global(ds, "train_4k")
    full_6nd = 6 * ds.param_count() * 4096 * 256
    assert f < 0.25 * full_6nd  # 37B active of 671B total


def test_cache_bytes_family_ordering():
    """SSM O(1) << SWA O(window) << dense O(S) for the same shape."""
    mamba = cache_bytes_global(C.get_config("mamba2-130m"), "decode_32k")
    danube = cache_bytes_global(C.get_config("h2o-danube-3-4b"), "decode_32k")
    qwen = cache_bytes_global(C.get_config("qwen1.5-110b"), "decode_32k")
    mla = cache_bytes_global(C.get_config("deepseek-v3-671b"), "decode_32k")
    assert mamba < danube < qwen
    # MLA latent cache beats raw GQA at the same context despite 61 layers
    per_layer_mla = mla / 61
    per_layer_gqa = qwen / 80
    assert per_layer_mla < per_layer_gqa


def test_decode_memory_includes_cache():
    cfg = C.get_config("qwen1.5-110b")
    t32 = roofline_terms(cfg, "decode_32k", MeshInfo.single())
    assert t32["memory"] > 0
    assert t32["dominant"] in ("memory", "collective")


def test_accum_halving_halves_train_collective_term():
    cfg = C.get_config("deepseek-v3-671b")
    t8 = roofline_terms(cfg, "train_4k", MeshInfo.multi(), accum=8)
    t4 = roofline_terms(cfg, "train_4k", MeshInfo.multi(), accum=4)
    assert t4["collective"] < 0.62 * t8["collective"]  # §Perf iteration 3
    assert t4["roofline_fraction"] > 1.5 * t8["roofline_fraction"]

"""Execution-backend parity: pallas kernels vs reference blockwise vs RWMA.

The acceptance bar for the kernel-backed path: ``encoder_bwma`` with
``backend="pallas"`` (interpret mode on CPU) must match both the row-major
baseline and the reference blockwise backend to <= 1e-4 max abs error on
BERT-base-shaped inputs, including ragged (non-block-multiple) shapes.
"""
import jax
import numpy as np
import pytest

from repro.core import blockwise as bw
from repro.core import encoder as enc
from repro.core.backend import (
    BACKENDS,
    PallasBackend,
    ReferenceBackend,
    resolve_backend,
)
from repro.core.layout import BlockLayout


def _cfg(**kw):
    base = dict(seq_len=64, d_model=96, n_heads=3, d_head=32, d_ff=128,
                n_layers=1, block=16)
    base.update(kw)
    return enc.EncoderConfig(**base)


def _outputs(cfg, seed=0):
    params = enc.init_params(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (cfg.seq_len, cfg.d_model))
    bp = enc.block_params(params, cfg)
    y_rwma = enc.encoder_rwma(params, x, cfg)
    y_ref = enc.encoder_bwma(bp, x, cfg, backend="reference")
    y_pal = enc.encoder_bwma(bp, x, cfg, backend="pallas", interpret=True)
    return np.asarray(y_rwma), np.asarray(y_ref), np.asarray(y_pal)


def test_resolve_backend():
    assert set(BACKENDS) >= {"reference", "pallas"}
    assert isinstance(resolve_backend(None), ReferenceBackend)
    assert isinstance(resolve_backend("reference"), ReferenceBackend)
    pb = resolve_backend("pallas", interpret=True)
    assert isinstance(pb, PallasBackend) and pb.interpret
    assert resolve_backend(pb) is pb
    # auto (None) and the explicitly-resolved value share one instance/cache
    assert resolve_backend("pallas") is resolve_backend(
        "pallas", interpret=jax.default_backend() != "tpu"
    )
    with pytest.raises(ValueError):
        resolve_backend("no-such-backend")
    with pytest.raises(ValueError):
        resolve_backend("reference", interpret=True)  # not a silent no-op


def test_pallas_matches_reference_ragged():
    """seq_len, d_model AND d_head all non-multiples of the block: the
    padding/masking path (incl. the per-head padded merge) end to end."""
    cfg = _cfg(seq_len=45, d_model=72, n_heads=2, d_head=20, d_ff=80,
               n_layers=2, block=16)
    y_rwma, y_ref, y_pal = _outputs(cfg, seed=2)
    np.testing.assert_allclose(y_ref, y_rwma, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(y_pal, y_rwma, rtol=5e-4, atol=5e-4)
    assert np.abs(y_pal - y_ref).max() <= 1e-4


def test_pallas_matches_reference_bert_base_shaped():
    """The paper's evaluation shape (512 x 768, 12 heads x 64, ff 3072) at
    the TPU-native 128 block — d_head 64 exercises the padded head merge."""
    cfg = enc.EncoderConfig(seq_len=512, d_model=768, n_heads=12, d_head=64,
                            d_ff=3072, n_layers=1, block=128)
    y_rwma, y_ref, y_pal = _outputs(cfg, seed=4)
    np.testing.assert_allclose(y_ref, y_rwma, rtol=5e-4, atol=5e-4)
    assert np.abs(y_pal - y_ref).max() <= 1e-4
    assert np.abs(y_pal - y_rwma).max() <= 5e-4


def test_batched_input_both_backends():
    """Leading batch dims run as one batched kernel call per op."""
    cfg = _cfg(seq_len=32, d_model=48, n_heads=2, d_head=16, d_ff=64)
    params = enc.init_params(jax.random.PRNGKey(6), cfg)
    bp = enc.block_params(params, cfg)
    xB = jax.random.normal(jax.random.PRNGKey(7), (2, cfg.seq_len, cfg.d_model))
    per_sample = np.stack([
        np.asarray(enc.encoder_bwma(bp, xB[i], cfg)) for i in range(2)
    ])
    for backend in ("reference", "pallas"):
        kw = {"interpret": True} if backend == "pallas" else {}
        yB = enc.encoder_bwma(bp, xB, cfg, backend=backend, **kw)
        assert yB.shape == (2, cfg.seq_len, cfg.d_model)
        np.testing.assert_allclose(np.asarray(yB), per_sample, rtol=2e-5, atol=2e-5)


def test_backend_ops_headwise_parity():
    """Op-level parity with a heads leading dim (the collapsed per-head loop)."""
    lo = BlockLayout(16, 16)
    h, s, dh = 3, 48, 32
    ref, pal = ReferenceBackend(), PallasBackend(interpret=True)
    q, k, v = (
        bw.Blocked(jax.random.normal(jax.random.PRNGKey(i), (h, s // 16, dh // 16, 16, 16)),
                   (s, dh), lo)
        for i in (8, 9, 10)
    )
    got = pal.attention(q, k, v, scale=0.125)
    want = ref.attention(q, k, v, scale=0.125)
    np.testing.assert_allclose(np.asarray(got.data), np.asarray(want.data),
                               rtol=2e-5, atol=2e-5)
    sm_got, sm_want = pal.softmax(q), ref.softmax(q)
    np.testing.assert_allclose(np.asarray(sm_got.data), np.asarray(sm_want.data),
                               rtol=2e-5, atol=2e-5)

"""Blocked operators vs their row-major oracles (paper §3.2 coverage)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import blockwise as bw
from repro.core.layout import BlockLayout
from repro.kernels import ref


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(2, 50), k=st.integers(2, 50), n=st.integers(2, 50),
    blk=st.sampled_from([4, 8, 16]),
)
def test_bw_matmul_property(m, k, n, blk):
    lo = BlockLayout(blk, blk)
    a, b = _rand(m, (m, k)), _rand(n + 100, (k, n))
    out = bw.bw_matmul(bw.block(a, lo), bw.block(b, lo))
    np.testing.assert_allclose(
        np.asarray(out.unblock()), np.asarray(ref.matmul_ref(a, b)),
        rtol=2e-5, atol=2e-5,
    )


@settings(max_examples=25, deadline=None)
@given(m=st.integers(2, 40), n=st.integers(2, 60), blk=st.sampled_from([4, 8, 16]))
def test_bw_softmax_property(m, n, blk):
    lo = BlockLayout(blk, blk)
    x = _rand(m * 91 + n, (m, n)) * 3
    out = bw.bw_softmax(bw.block(x, lo)).unblock()
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.softmax_ref(x)), rtol=1e-5, atol=1e-6
    )
    # rows sum to 1 (with padding masked out)
    np.testing.assert_allclose(np.asarray(out).sum(-1), 1.0, rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(2, 40), n=st.integers(2, 60), blk=st.sampled_from([4, 8]))
def test_bw_layernorm_property(m, n, blk):
    lo = BlockLayout(blk, blk)
    x = _rand(m * 13 + n, (m, n))
    g, b = _rand(1, (n,)), _rand(2, (n,))
    out = bw.bw_layernorm(
        bw.block(x, lo), bw.block_vector(g, lo), bw.block_vector(b, lo)
    ).unblock()
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.layernorm_ref(x, g, b)),
        rtol=1e-4, atol=1e-4,
    )


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 50), n=st.integers(1, 50), blk=st.sampled_from([4, 16]))
def test_bw_transpose_property(m, n, blk):
    lo = BlockLayout(blk, blk)
    x = _rand(m + 997 * n, (m, n))
    out = bw.bw_transpose(bw.block(x, lo)).unblock()
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x).T)


def test_transpose_involution():
    lo = BlockLayout(8, 8)
    x = _rand(5, (24, 40))
    b = bw.block(x, lo)
    np.testing.assert_array_equal(
        np.asarray(bw.bw_transpose(bw.bw_transpose(b)).unblock()), np.asarray(x)
    )

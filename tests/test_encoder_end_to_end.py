"""End-to-end blocked encoder (the paper's BERT case study, reduced dims)."""
import jax
import numpy as np

from repro.core import encoder as enc


def _cfg(**kw):
    base = dict(seq_len=64, d_model=96, n_heads=3, d_head=32, d_ff=128,
                n_layers=2, block=16)
    base.update(kw)
    return enc.EncoderConfig(**base)


def test_bwma_encoder_matches_rwma():
    """§3.2: the whole encoder runs blocked, converting only at the edges,
    and matches the row-major reference layer-for-layer."""
    cfg = _cfg()
    params = enc.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (cfg.seq_len, cfg.d_model))
    y_r = enc.encoder_rwma(params, x, cfg)
    y_b = enc.encoder_bwma(enc.block_params(params, cfg), x, cfg)
    np.testing.assert_allclose(np.asarray(y_b), np.asarray(y_r),
                               rtol=5e-4, atol=5e-4)


def test_block8_also_works():
    cfg = _cfg(block=8)
    params = enc.init_params(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (cfg.seq_len, cfg.d_model))
    y_r = enc.encoder_rwma(params, x, cfg)
    y_b = enc.encoder_bwma(enc.block_params(params, cfg), x, cfg)
    np.testing.assert_allclose(np.asarray(y_b), np.asarray(y_r),
                               rtol=5e-4, atol=5e-4)


def test_no_nans_and_shape():
    cfg = _cfg()
    params = enc.init_params(jax.random.PRNGKey(4), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (cfg.seq_len, cfg.d_model))
    y = enc.encoder_bwma(enc.block_params(params, cfg), x, cfg)
    assert y.shape == (cfg.seq_len, cfg.d_model)
    assert np.isfinite(np.asarray(y)).all()

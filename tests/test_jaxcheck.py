"""Fixture tests for the compiled-artifact linter (repro.analysis.jaxcheck).

Per RPJ rule: a seeded-violation spec that must produce a finding, the
clean counterpart, and waiver suppression.  Plus budgets-file round-
tripping and the acceptance gate: the serving engine's own jitted-step
inventory is clean against the checked-in ``jaxcheck.budgets``.
"""
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.jaxcheck import (
    RULE_IDS,
    Budgets,
    Finding,
    format_budgets,
    load_budgets,
)
from repro.analysis.jaxcheck.harness import (
    ProbeSet,
    StepSpec,
    collective_stats,
    compile_step,
    gather_stats,
    measure,
    parse_aliased_params,
)
from repro.analysis.jaxcheck.inventory import InventoryConfig, serving_inventory
from repro.analysis.jaxcheck.rules import RULES, run_rules

REPO = Path(__file__).resolve().parent.parent


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _findings(spec, budgets=None, select=None):
    cs = compile_step(spec)
    budgets = budgets or Budgets()
    out = []
    for rid in select or RULE_IDS:
        out.extend(
            f for f in RULES[rid]([cs], None, budgets)
            if not budgets.waived(f.rule, f.step)
        )
    return out


# --------------------------------------------------------------------------
# RPJ101 — donation-effectiveness
# --------------------------------------------------------------------------

def _dropped_donation_spec():
    # arg 0 is donated but no output can reuse its buffer (shape/dtype
    # mismatch) -> XLA drops the donation, no alias entry.  NB a donated-
    # but-*unused* same-shape buffer still gets aliased; the drop needs the
    # buffer to be unusable.
    return StepSpec(
        name="drop", fn=lambda x, y: jnp.sum(y)[None].astype(jnp.int32),
        args=(_sds((64,)), _sds((64,))), donate_argnums=(0,),
    )


def test_rpj101_seeded_dropped_donation():
    found = _findings(_dropped_donation_spec(), select=["RPJ101"])
    assert [f.rule for f in found] == ["RPJ101"]
    assert "donation became a copy" in found[0].message


def test_rpj101_clean_effective_donation():
    spec = StepSpec(
        name="ok", fn=lambda x, y: x + y,
        args=(_sds((64,)), _sds((64,))), donate_argnums=(0,),
    )
    assert _findings(spec, select=["RPJ101"]) == []


def test_rpj101_waiver():
    budgets = Budgets(waivers={"drop": {"RPJ101"}})
    assert _findings(_dropped_donation_spec(), budgets,
                     select=["RPJ101"]) == []


# --------------------------------------------------------------------------
# RPJ102 — materialized gather
# --------------------------------------------------------------------------

def _gather_spec():
    # gathers 512 rows of 256 floats = 512 KiB output
    return StepSpec(
        name="big_gather",
        fn=lambda table, idx: jnp.take(table, idx, axis=0),
        args=(_sds((1024, 256)), _sds((512,), jnp.int32)),
    )


def test_rpj102_seeded_over_budget():
    budgets = Budgets(steps={"big_gather": {"max_gather_bytes": 1024}})
    found = _findings(_gather_spec(), budgets, select=["RPJ102"])
    assert [f.rule for f in found] == ["RPJ102"]
    assert "exceeds budget" in found[0].message


def test_rpj102_seeded_unbudgeted():
    found = _findings(_gather_spec(), select=["RPJ102"])
    assert [f.rule for f in found] == ["RPJ102"]
    assert "no max_gather_bytes budget" in found[0].message


def test_rpj102_clean_within_budget():
    budgets = Budgets(steps={"big_gather": {"max_gather_bytes": 512 * 1024}})
    assert _findings(_gather_spec(), budgets, select=["RPJ102"]) == []


def test_gather_stats_sees_nested_pjit_gather():
    # jnp.take hides its gather inside a nested pjit eqn
    cs = compile_step(_gather_spec())
    stats = gather_stats(cs.jaxpr)
    assert stats and max(s["output_bytes"] for s in stats) == 512 * 256 * 4


# --------------------------------------------------------------------------
# RPJ103 — dtype-promotion drift
# --------------------------------------------------------------------------

def test_rpj103_seeded_f64_upcast():
    with jax.experimental.enable_x64():
        spec = StepSpec(
            name="upcast", fn=lambda x: x.astype(jnp.float64) * 2.0,
            args=(_sds((16,), jnp.float32),),
        )
        found = _findings(spec, select=["RPJ103"])
    assert [f.rule for f in found] == ["RPJ103"]
    assert "float64" in found[0].message


def test_rpj103_clean_f32_converts():
    spec = StepSpec(
        name="ok", fn=lambda x: x.astype(jnp.float32) + 1.0,
        args=(_sds((16,), jnp.int32),),
    )
    assert _findings(spec, select=["RPJ103"]) == []


# --------------------------------------------------------------------------
# RPJ104 — retrace closure
# --------------------------------------------------------------------------

def test_rpj104_seeded_plan_escapes_closure():
    spec = StepSpec(
        name="escape", fn=lambda x: x * 2.0, args=(_sds((8,)),),
        signature_plan=(8, 3), signature_closure=(1, 2, 4, 8),
    )
    found = _findings(spec, select=["RPJ104"])
    assert [f.rule for f in found] == ["RPJ104"]
    assert "[3]" in found[0].message


def test_rpj104_seeded_probe_signature_leak():
    # the probe feeds two dtypes through one jit -> 2 cache entries, not 1
    spec = StepSpec(
        name="leak", fn=lambda x: x * 2, args=(_sds((8,)),),
        probe=ProbeSet(
            keys=(0, 1),
            make_args=lambda k: (
                jnp.zeros((8,), jnp.float32 if k == 0 else jnp.int32),
            ),
            expected_entries=1,
        ),
    )
    found = _findings(spec, select=["RPJ104"])
    assert [f.rule for f in found] == ["RPJ104"]
    assert "signature leak" in found[0].message


def test_rpj104_clean_probe():
    spec = StepSpec(
        name="ok", fn=lambda x: x * 2, args=(_sds((8,)),),
        signature_plan=(8,), signature_closure=(8,),
        probe=ProbeSet(
            keys=(0, 1),
            make_args=lambda k: (jnp.zeros((8,), jnp.float32),),
            expected_entries=1,
        ),
    )
    assert _findings(spec, select=["RPJ104"]) == []


# --------------------------------------------------------------------------
# RPJ105 — memory-budget regression
# --------------------------------------------------------------------------

def _mem_spec():
    return StepSpec(
        name="mem", fn=lambda x: jnp.dot(x, x.T).sum(),
        args=(_sds((64, 64)),),
    )


def test_rpj105_seeded_over_budget():
    budgets = Budgets(steps={"mem": {
        "temp_size_in_bytes": 0,
        "argument_size_in_bytes": 0,
        "output_size_in_bytes": 0,
    }})
    found = _findings(_mem_spec(), budgets, select=["RPJ105"])
    assert found and all(f.rule == "RPJ105" for f in found)
    assert any("exceeds budget" in f.message for f in found)


def test_rpj105_seeded_unbudgeted():
    found = _findings(_mem_spec(), select=["RPJ105"])
    assert found and all("no budget" in f.message for f in found)


def test_rpj105_clean_within_tolerance():
    cs = compile_step(_mem_spec())
    budgets = Budgets(steps={"mem": dict(cs.memory)})
    assert _findings(_mem_spec(), budgets, select=["RPJ105"]) == []


# --------------------------------------------------------------------------
# harness pieces
# --------------------------------------------------------------------------

def test_parse_aliased_params():
    hlo = textwrap.dedent("""
        HloModule jit_f, input_output_alias={ {0}: (1, {}, may-alias),
        {1}: (3, {}, may-alias) }, entry_computation_layout={...}
    """)
    assert parse_aliased_params(hlo) == {1, 3}
    assert parse_aliased_params("HloModule jit_f, entry={...}") == frozenset()


def test_measure_fields():
    rec = measure(compile_step(_gather_spec()))
    assert rec["max_gather_bytes"] == 512 * 256 * 4
    assert "temp_size_in_bytes" in rec


# --------------------------------------------------------------------------
# budgets file round-trip
# --------------------------------------------------------------------------

def test_budgets_round_trip(tmp_path):
    measured = {"decode_step": {"temp_size_in_bytes": 100,
                                "max_gather_bytes": 42}}
    waivers = {"decode_step": {"RPJ103"}, "global": {"RPJ102"}}
    text = format_budgets(measured, tolerance=0.25, allowed_widest="float32",
                          waivers=waivers)
    p = tmp_path / "jaxcheck.budgets"
    p.write_text(text, encoding="utf-8")
    b = load_budgets(p)
    assert b.steps == measured
    assert b.tolerance == 0.25
    assert b.waived("RPJ103", "decode_step")
    assert b.waived("RPJ102", "anything")  # global waiver
    assert not b.waived("RPJ101", "decode_step")
    assert b.allowed("decode_step", "temp_size_in_bytes", 125)
    assert not b.allowed("decode_step", "temp_size_in_bytes", 126)


def test_budgets_rejects_unknown_rule(tmp_path):
    p = tmp_path / "bad.budgets"
    p.write_text("[s]\nwaive = RPJ999\n", encoding="utf-8")
    with pytest.raises(ValueError, match="unknown rule"):
        load_budgets(p)


# --------------------------------------------------------------------------
# CLI exit codes
# --------------------------------------------------------------------------

def test_cli_exit_codes(tmp_path, capsys):
    import json

    from repro.analysis.jaxcheck.__main__ import main

    assert main(["--list-rules"]) == 0
    capsys.readouterr()

    # clean run against the checked-in budgets, with a JSON report
    report_path = tmp_path / "BENCH_jaxcheck.json"
    rc = main(["--budgets", str(REPO / "jaxcheck.budgets"),
               "--json-out", str(report_path)])
    capsys.readouterr()
    assert rc == 0
    report = json.loads(report_path.read_text(encoding="utf-8"))
    assert report["status"] == "clean" and report["findings"] == []
    assert report["n_steps"] == len(report["steps"]) > 0

    # seeded regression: zeroed budgets must fail with findings
    bad = tmp_path / "bad.budgets"
    bad.write_text(
        "[global]\ntolerance = 0.0\n\n[decode_step]\n"
        "temp_size_in_bytes = 1\nmax_gather_bytes = 1\n",
        encoding="utf-8",
    )
    rc = main(["--budgets", str(bad), "--select", "RPJ102", "RPJ105",
               "--json-out", str(report_path)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "RPJ102" in out and "RPJ105" in out
    report = json.loads(report_path.read_text(encoding="utf-8"))
    assert report["status"] == "findings"


# --------------------------------------------------------------------------
# acceptance gate: the engine's own inventory is clean
# --------------------------------------------------------------------------

def test_engine_inventory_is_clean():
    """The serving engine's compiled hot-path steps pass every RPJ rule
    against the checked-in budgets (re-baseline intentional changes with
    `python -m repro.analysis.jaxcheck --write-budgets`)."""
    budgets_file = REPO / "jaxcheck.budgets"
    assert budgets_file.exists(), "jaxcheck.budgets must be checked in"
    budgets = load_budgets(budgets_file)
    inv = serving_inventory()
    steps = [compile_step(spec) for spec in inv.specs]
    findings = run_rules(steps, inv, budgets)
    assert not findings, "\n".join(f.format() for f in findings)
    # and the inventory covers the steps the budgets file gates
    names = {cs.name for cs in steps}
    assert set(budgets.steps) <= names | {"global"}


# --------------------------------------------------------------------------
# RPJ106 — collective-traffic budget
# --------------------------------------------------------------------------

_SHARDED_HLO = """\
HloModule jit_step, is_scheduled=true

ENTRY main {
  %p0 = f32[2,48]{1,0} parameter(0)
  %all-gather = f32[2,96]{1,0} all-gather(f32[2,48]{1,0} %p0), dimensions={1}
  %all-reduce-start = f32[2,96]{1,0} all-reduce-start(f32[2,96]{1,0} %all-gather)
  %all-reduce-done = f32[2,96]{1,0} all-reduce-done(%all-reduce-start)
  %reduce-scatter = (f32[2,48]{1,0}, f32[4]{0}) reduce-scatter(%all-reduce-done)
  ROOT %out = f32[2,48]{1,0} get-tuple-element(%reduce-scatter), index=0
}
"""


def test_collective_stats_parses_hlo_once_per_async_pair():
    colls = collective_stats(_SHARDED_HLO)
    # the -done must not double-count its -start; the tuple shape sums
    assert [c["op"] for c in colls] == [
        "all-gather", "all-reduce", "reduce-scatter"
    ]
    assert [c["output_bytes"] for c in colls] == [
        2 * 96 * 4, 2 * 96 * 4, 2 * 48 * 4 + 4 * 4
    ]
    assert collective_stats("ENTRY main { ROOT %x = f32[4] add(...) }") == []


class _FakeArtifact:
    def __init__(self, hlo):
        self._hlo = hlo

    def hlo_text(self):
        return self._hlo


class _FakeCompiledStep:
    def __init__(self, name, hlo):
        self.name = name
        self.artifact = _FakeArtifact(hlo)


def test_rpj106_seeded_unbudgeted_and_over_budget():
    cs = _FakeCompiledStep("sharded_step", _SHARDED_HLO)
    found = RULES["RPJ106"]([cs], None, Budgets())
    assert found and "no collective_bytes budget" in found[0].message
    tight = Budgets(steps={"sharded_step": {"collective_bytes": 8}},
                    tolerance=0.0)
    found = RULES["RPJ106"]([cs], None, tight)
    assert found and "exceeds budget" in found[0].message


def test_rpj106_clean_within_budget_and_no_collectives():
    cs = _FakeCompiledStep("sharded_step", _SHARDED_HLO)
    total = sum(c["output_bytes"] for c in collective_stats(_SHARDED_HLO))
    ok = Budgets(steps={"sharded_step": {"collective_bytes": total}})
    assert RULES["RPJ106"]([cs], None, ok) == []
    # a single-device module (no collectives) passes with no budget at all
    clean = _FakeCompiledStep("local", "ENTRY main { ROOT %x = f32[4] neg() }")
    assert RULES["RPJ106"]([clean], None, Budgets()) == []


# --------------------------------------------------------------------------
# sharded inventory (needs simulated devices; CI `mesh` job runs this)
# --------------------------------------------------------------------------

@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >= 2 devices")
def test_sharded_inventory_donation_and_collectives():
    """Acceptance gate: on a (1, 2) mesh the engine's compiled sharded
    steps keep every pool donation aliased (RPJ101 — donation survives
    sharding), carry real collectives for RPJ106 to budget, and the
    donated pool's alias bytes drop by the TP factor vs single-device."""
    inv = serving_inventory(InventoryConfig(mesh="1x2"))
    steps = [compile_step(spec) for spec in inv.specs]
    findings = run_rules(steps, inv, Budgets(), select=["RPJ101"])
    assert not findings, "\n".join(f.format() for f in findings)
    decode = next(cs for cs in steps if cs.name == "decode_step")
    assert collective_stats(decode.artifact.hlo_text()), (
        "sharded decode step should contain cross-device collectives"
    )
    single = serving_inventory()
    dec_spec = next(s for s in single.specs if s.name == "decode_step")
    alias_single = compile_step(dec_spec).memory["alias_size_in_bytes"]
    assert decode.memory["alias_size_in_bytes"] * 2 == alias_single
    # checked-in mesh budgets keep the sharded inventory clean end to end
    mesh_budgets = REPO / "jaxcheck_mesh.budgets"
    assert mesh_budgets.exists(), "jaxcheck_mesh.budgets must be checked in"
    findings = run_rules(steps, inv, load_budgets(mesh_budgets))
    assert not findings, "\n".join(f.format() for f in findings)

"""Per-kernel validation: shape/dtype sweeps vs the ref.py pure-jnp oracles.

Kernels run in interpret=True mode on CPU (the TPU lowering path is exercised
structurally: BlockSpecs, grids and VMEM block shapes are identical).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import blockwise as bw
from repro.core.layout import BlockLayout, from_blockwise, to_blockwise
from repro.kernels import ref
from repro.kernels.bwma_fused_ffn import bwma_fused_ffn
from repro.kernels.bwma_gemm import bwma_gemm
from repro.kernels.bwma_layernorm import bwma_layernorm
from repro.kernels.bwma_softmax import bwma_softmax
from repro.kernels.rwma_gemm import rwma_gemm

GEMM_SHAPES = [
    (16, 16, 16), (32, 64, 16), (48, 80, 64), (96, 32, 48), (128, 128, 128),
    (17, 33, 9),  # non-multiples: exercise padding
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("m,k,n", GEMM_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_bwma_gemm_sweep(m, k, n, dtype):
    lo = BlockLayout(16, 16)
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k), dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), dtype)
    out = bwma_gemm(to_blockwise(a, lo), to_blockwise(b, lo), interpret=True)
    got = from_blockwise(out, lo, (m, n))
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref.matmul_ref(a, b)), **_tol(dtype)
    )


@pytest.mark.parametrize("m,k,n", [(32, 64, 16), (64, 32, 64)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_rwma_gemm_sweep(m, k, n, dtype):
    a = jax.random.normal(jax.random.PRNGKey(2), (m, k), dtype)
    b = jax.random.normal(jax.random.PRNGKey(3), (k, n), dtype)
    out = rwma_gemm(a, b, bm=16, bk=16, bn=16, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref.matmul_ref(a, b)), **_tol(dtype)
    )


def test_bwma_rwma_agree():
    """The two arrangements are functionally identical — the paper's premise."""
    a = jax.random.normal(jax.random.PRNGKey(4), (64, 96))
    b = jax.random.normal(jax.random.PRNGKey(5), (96, 32))
    lo = BlockLayout(16, 16)
    out_b = from_blockwise(
        bwma_gemm(to_blockwise(a, lo), to_blockwise(b, lo), interpret=True),
        lo, (64, 32),
    )
    out_r = rwma_gemm(a, b, bm=16, bk=16, bn=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_r), rtol=1e-6)


@pytest.mark.parametrize("m,n", [(16, 16), (32, 48), (40, 70), (8, 130)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_bwma_softmax_sweep(m, n, dtype):
    lo = BlockLayout(16, 16)
    x = jax.random.normal(jax.random.PRNGKey(6), (m, n), dtype) * 2
    out = bwma_softmax(to_blockwise(x, lo), n, interpret=True)
    got = from_blockwise(out, lo, (m, n))
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref.softmax_ref(x)),
        **_tol(dtype),
    )


@pytest.mark.parametrize("m,n", [(16, 32), (40, 70), (64, 256)])
def test_bwma_layernorm_sweep(m, n):
    lo = BlockLayout(16, 16)
    x = jax.random.normal(jax.random.PRNGKey(7), (m, n))
    g = jax.random.normal(jax.random.PRNGKey(8), (n,))
    b = jax.random.normal(jax.random.PRNGKey(9), (n,))
    out = bwma_layernorm(
        to_blockwise(x, lo), bw.block_vector(g, lo), bw.block_vector(b, lo),
        n, interpret=True,
    )
    got = from_blockwise(out, lo, (m, n))
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.layernorm_ref(x, g, b)),
        rtol=1e-4, atol=1e-4,
    )


@pytest.mark.parametrize("m,k,n", [(32, 64, 32), (48, 96, 16)])
def test_bwma_fused_ffn_sweep(m, k, n):
    lo = BlockLayout(16, 16)
    a = jax.random.normal(jax.random.PRNGKey(10), (m, k))
    w = jax.random.normal(jax.random.PRNGKey(11), (k, n))
    bias = jax.random.normal(jax.random.PRNGKey(12), (n,))
    out = bwma_fused_ffn(
        to_blockwise(a, lo), to_blockwise(w, lo), bw.block_vector(bias, lo),
        interpret=True,
    )
    got = from_blockwise(out, lo, (m, n))
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.ffn_ref(a, w, bias)), rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("s,dh", [(32, 16), (48, 32), (45, 20)])
def test_bwma_attention_sweep(s, dh):
    """Fused scores->softmax->@V vs the composed oracle, incl. ragged s/dh."""
    from repro.kernels.bwma_attention import bwma_attention

    lo = BlockLayout(16, 16)
    scale = 1.0 / dh ** 0.5
    q = jax.random.normal(jax.random.PRNGKey(20), (s, dh))
    k = jax.random.normal(jax.random.PRNGKey(21), (s, dh))
    v = jax.random.normal(jax.random.PRNGKey(22), (s, dh))
    out = bwma_attention(
        to_blockwise(q, lo), to_blockwise(k, lo), to_blockwise(v, lo),
        scale=scale, s_logical=s, interpret=True,
    )
    got = from_blockwise(out, lo, (s, dh))
    want = ref.softmax_ref(q @ k.T * scale) @ v
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_kernels_accept_blocked_and_leading_dims():
    """Kernels take Blocked wrappers and batch/head leading dims directly."""
    lo = BlockLayout(16, 16)
    x = jax.random.normal(jax.random.PRNGKey(23), (2, 3, 40, 48))
    w = jax.random.normal(jax.random.PRNGKey(24), (48, 32))
    xb = bw.block(x, lo)  # data (2, 3, gm, gn, 16, 16)
    wb = bw.block(w, lo)
    out = bwma_gemm(xb, wb, interpret=True)
    assert isinstance(out, bw.Blocked) and out.shape == (40, 32)
    assert out.data.shape[:2] == (2, 3)
    want = np.einsum("...mk,kn->...mn", np.asarray(x), np.asarray(w))
    np.testing.assert_allclose(np.asarray(out.unblock()), want,
                               rtol=2e-5, atol=2e-5)
    sm = bwma_softmax(xb, interpret=True)
    np.testing.assert_allclose(
        np.asarray(sm.unblock()), np.asarray(ref.softmax_ref(x)),
        rtol=2e-5, atol=2e-5,
    )


@pytest.mark.parametrize("m,n", [(32, 32), (48, 80), (16, 128)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_bwma_transpose_sweep(m, n, dtype):
    from repro.kernels.bwma_transpose import bwma_transpose
    lo = BlockLayout(16, 16)
    x = jax.random.normal(jax.random.PRNGKey(13), (m, n), dtype)
    out = bwma_transpose(to_blockwise(x, lo), interpret=True)
    got = from_blockwise(out, lo, (n, m))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x).T)

"""Property tests for the BWMA layout itself (the paper's core object)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.layout import (
    BlockLayout,
    blockwise_1d_view,
    from_blockwise,
    to_blockwise,
)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 70),
    n=st.integers(1, 70),
    bm=st.sampled_from([4, 8, 16]),
    bn=st.sampled_from([4, 8, 16]),
)
def test_roundtrip_property(m, n, bm, bn):
    """from_blockwise(to_blockwise(x)) == x for any shape/block combo."""
    lo = BlockLayout(bm, bn)
    x = np.random.default_rng(m * 71 + n).standard_normal((m, n)).astype(np.float32)
    xb = to_blockwise(jnp.asarray(x), lo)
    assert xb.shape == lo.blocked_shape((m, n))
    back = from_blockwise(xb, lo, (m, n))
    np.testing.assert_array_equal(np.asarray(back), x)


@settings(max_examples=20, deadline=None)
@given(
    gm=st.integers(1, 4), gn=st.integers(1, 4), bm=st.sampled_from([4, 8])
)
def test_blocks_are_contiguous_in_memory(gm, gn, bm):
    """The defining property (paper Fig. 4d): block (i, j) occupies one
    contiguous range of the stored 1-D array."""
    lo = BlockLayout(bm, bm)
    m, n = gm * bm, gn * bm
    x = np.arange(m * n, dtype=np.float32).reshape(m, n)
    xb = np.asarray(to_blockwise(jnp.asarray(x), lo))
    flat = blockwise_1d_view(xb)
    for i in range(gm):
        for j in range(gn):
            start = (i * gn + j) * bm * bm
            blk = flat[start : start + bm * bm].reshape(bm, bm)
            np.testing.assert_array_equal(
                blk, x[i * bm : (i + 1) * bm, j * bm : (j + 1) * bm]
            )


def test_row_major_is_not_blockwise():
    """RWMA (row-major) interleaves blocks — the property above must FAIL for
    the plain array, otherwise the two arrangements would be identical."""
    m = n = 8
    x = np.arange(m * n, dtype=np.float32).reshape(m, n)
    flat_rwma = x.reshape(-1)
    blk = flat_rwma[:16].reshape(4, 4)
    assert not np.array_equal(blk, x[:4, :4])


def test_padding_cropped():
    lo = BlockLayout(16, 16)
    x = jnp.ones((10, 20))
    xb = to_blockwise(x, lo)
    assert xb.shape == (1, 2, 16, 16)
    assert float(jnp.sum(xb)) == 200.0  # padding is zeros
    back = from_blockwise(xb, lo, (10, 20))
    assert back.shape == (10, 20)


def test_bad_block_rejected():
    with pytest.raises(ValueError):
        BlockLayout(0, 4)

"""Memory-model invariants + reproduction of the paper's headline effects."""
import numpy as np
import pytest

from repro.core import memmodel as mm

SMALL = mm.WorkloadConfig(seq=128, d_model=192, n_heads=3, d_head=64, d_ff=768)


def test_gemm_trace_bytes_equal_between_layouts():
    """Both arrangements move the same data (same tiles, same elements) —
    only the ORDER differs.  Total line-visits must match."""
    ta, _ = mm.gemm_trace(64, 64, 64, 16, "rwma", 1, 0, 1 << 22, 2 << 22)
    tb, _ = mm.gemm_trace(64, 64, 64, 16, "bwma", 1, 0, 1 << 22, 2 << 22)
    # same number of tile loads x (lines per tile may differ by layout
    # granularity but total unique lines per matrix are equal)
    assert len(np.unique(ta)) == len(np.unique(tb))


def test_bwma_trace_is_more_sequential():
    ta, _ = mm.gemm_trace(128, 128, 128, 16, "rwma", 1, 0, 1 << 22, 2 << 22)
    tb, _ = mm.gemm_trace(128, 128, 128, 16, "bwma", 1, 0, 1 << 22, 2 << 22)
    seq_r = mm._sequential(ta).mean()
    seq_b = mm._sequential(tb).mean()
    assert seq_b > seq_r  # the defining property of the arrangement


def test_dm_cache_sim_basics():
    # repeated access to one line: 1 miss then hits
    lines = np.zeros(100, dtype=np.int64)
    miss = mm._dm_miss(lines, 32 * 1024)
    assert miss.sum() == 1
    # streaming distinct lines: all miss
    lines = np.arange(10_000, dtype=np.int64)
    assert mm._dm_miss(lines, 32 * 1024).sum() == 10_000


def test_paper_effects_small_workload():
    """Direction of every headline result on a reduced BERT layer:
    speedup > 1, fewer L1 misses, fewer L2 accesses, non-GEMM share grows."""
    accel = mm.AccelSpec.sa(16)
    r = mm.simulate_layer(SMALL, accel, "rwma")
    b = mm.simulate_layer(SMALL, accel, "bwma")
    assert r["total"].cycles > b["total"].cycles  # speedup
    assert r["total"].l1_misses > b["total"].l1_misses
    assert r["total"].l2_accesses > b["total"].l2_accesses
    ng_r = sum(r[c].cycles for c in mm.NON_GEMM_COMPONENTS) / r["total"].cycles
    ng_b = sum(b[c].cycles for c in mm.NON_GEMM_COMPONENTS) / b["total"].cycles
    assert ng_b > ng_r  # paper Fig. 7: non-GEMM share rises under BWMA


def test_multicore_scales_and_preserves_win():
    accel = mm.AccelSpec.sa(16)
    c1 = mm.simulate_layer(SMALL, accel, "bwma", cores=1)["total"].cycles
    c2 = mm.simulate_layer(SMALL, accel, "bwma", cores=2)["total"].cycles
    assert c2 < c1  # more cores help
    r2 = mm.simulate_layer(SMALL, accel, "rwma", cores=2)["total"].cycles
    b2 = mm.simulate_layer(SMALL, accel, "bwma", cores=2)["total"].cycles
    assert b2 < r2  # BWMA wins at every core count (paper Fig. 6b)


def test_conversion_overhead_is_negligible():
    """Paper §3.2: RWMA<->BWMA conversion ~0.1% of a 12-layer model."""
    frac = mm.conversion_overhead_fraction(SMALL, mm.AccelSpec.sa(16))
    assert frac < 0.01


@pytest.mark.slow
def test_paper_full_workload_speedup_band():
    """Full BERT-base layer (paper §4.1): single-core speedups must land in
    the paper's reported neighbourhood (2.3x-2.8x, we accept 1.8x-3.8x for
    the rebuilt instrument; see EXPERIMENTS.md for the calibration notes)."""
    wl = mm.WorkloadConfig()
    s = mm.speedup(wl, mm.AccelSpec.sa(16))
    assert 1.8 < s < 3.8

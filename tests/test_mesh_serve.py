"""Tensor-parallel paged serving: simulated-mesh parity with single-device.

The tentpole guarantee: mesh sharding is a *placement* change, not a
numerics change — the continuous engine's greedy outputs on a CPU-simulated
``(data, model)`` mesh are bit-identical to the single-device engine, for
every cache family (dense/GQA pages, MLA latent pages, MoE stacks),
including chunked prefill, preemption/recompute, shared-prefix COW, and
both decode backends (jnp gather oracle and pallas kernels).

Simulated meshes need ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
set **before jax initializes** (the CI ``mesh`` job does); with fewer
devices every test here self-skips.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.launch.mesh import make_serve_mesh
from repro.models import model as M
from repro.serve import Engine, EngineConfig, ServeConfig, Server

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs >= 4 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


def _dense_cfg(**over):
    """minicpm (dense MHA), heads lifted to divide a 4-way model axis."""
    cfg = C.get_config("minicpm-2b", smoke=True, dtype=jnp.float32)
    over = {"block": 8, **over}
    return dataclasses.replace(cfg, n_heads=8, n_kv_heads=8, **over)


def _moe_cfg(**over):
    """granite MoE, GQA heads lifted to divide a 4-way model axis."""
    cfg = C.get_config("granite-moe-3b-a800m", smoke=True, dtype=jnp.float32)
    return dataclasses.replace(
        cfg, block=8, n_heads=8, n_kv_heads=4, **over
    )


def _mla_cfg(**over):
    """The full DeepSeek-V3 shape (MLA latent pages + MoE + MTP).  Latent
    pools have no head axis and replicate; head parallelism is activation-
    side only, so the stock smoke head count serves any mesh."""
    cfg = C.get_config("deepseek-v3-671b", smoke=True, dtype=jnp.float32)
    return dataclasses.replace(cfg, block=8, **over)


def _prompts(cfg, sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
            for n in sizes]


def _run_engine(cfg, params, prompts, max_new, ec, mesh=None, stagger=2):
    eng = Engine(cfg, params, ec, mesh=mesh)
    for i, p in enumerate(prompts):
        eng.submit(p, max_new, rid=i, arrival_step=stagger * i)
    reqs = eng.run()
    assert all(r.state == "finished" for r in reqs)
    return eng, [np.asarray(r.out_tokens) for r in reqs]


def _assert_mesh_parity(cfg, prompts, max_new, ec, mesh):
    """Greedy tokens on ``mesh`` == single-device, token for token."""
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    _, base = _run_engine(cfg, params, prompts, max_new, ec)
    eng, out = _run_engine(cfg, params, prompts, max_new, ec, mesh=mesh)
    for b, o in zip(base, out):
        np.testing.assert_array_equal(o, b)
    return eng


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_mesh_parity_dense_chunked_prefill(backend):
    """Dense/GQA paged pools head-shard 4-way; chunked admission, slot
    re-fill, and both decode backends stay bit-identical — and each device
    holds 1/4 of the pool (minicpm's pools are all head-sharded)."""
    cfg = _dense_cfg(decode_backend=backend)
    mesh = make_serve_mesh("1x4")
    eng = _assert_mesh_parity(
        cfg, _prompts(cfg, (12, 9, 14)), 8,
        EngineConfig(max_seqs=2, max_len=32, page_size=8, backend=backend),
        mesh,
    )
    assert eng.kv.cache_bytes_per_device() == eng.kv.cache_bytes() // 4


def test_mesh_parity_server_static_waves():
    """The static-wave baseline engine on the same mesh: resident-TP
    weights, same greedy tokens."""
    cfg = _dense_cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts = _prompts(cfg, (12, 12))
    batch = {"tokens": jnp.asarray(np.stack(prompts))}
    base = Server(cfg, params, ServeConfig(max_len=64)).generate(batch, 8)
    out = Server(
        cfg, params, ServeConfig(max_len=64), mesh=make_serve_mesh("1x4")
    ).generate(batch, 8)
    np.testing.assert_array_equal(out, base)


def test_mesh_parity_moe_stack():
    """MoE (granite): expert FFN shards under the serve policy while the
    GQA pools head-shard.  Single-chunk prompts so the capacity dispatch
    sees one-shot token groups (the documented MoE chunking caveat —
    orthogonal to the mesh)."""
    cfg = _moe_cfg()
    _assert_mesh_parity(
        cfg, _prompts(cfg, (8, 7, 6), seed=1), 6,
        EngineConfig(max_seqs=2, max_len=32, page_size=8),
        make_serve_mesh("1x4"),
    )


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_mesh_parity_mla_latent_pages(backend):
    """DeepSeek MLA: latent pools replicate (no head axis) yet outputs stay
    bit-identical on the mesh, both backends."""
    cfg = _mla_cfg(decode_backend=backend)
    eng = _assert_mesh_parity(
        cfg, _prompts(cfg, (8, 7, 6), seed=1), 6,
        EngineConfig(max_seqs=2, max_len=32, page_size=8, backend=backend),
        make_serve_mesh("1x4"),
    )
    # replicated latent pools: every device holds the full pool
    assert eng.kv.cache_bytes_per_device() == eng.kv.cache_bytes()


def test_mesh_preemption_recompute_parity():
    """LIFO preemption + re-prefill over head-sharded pools: the recompute
    path (admission installs, COW, donation) stays bit-identical."""
    cfg = _dense_cfg(block=4)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts = _prompts(cfg, (10, 10, 10))
    ec = EngineConfig(max_seqs=3, max_len=20, page_size=4, num_pages=9)
    _, base = _run_engine(cfg, params, prompts, 10, ec, stagger=0)
    eng, out = _run_engine(
        cfg, params, prompts, 10, ec, mesh=make_serve_mesh("1x4"), stagger=0
    )
    assert sum(r.n_preemptions for r in (
        eng.sched.finished[i].stats for i in range(3))) >= 1
    for b, o in zip(base, out):
        np.testing.assert_array_equal(o, b)


def test_mesh_shared_prefix_cow_parity():
    """Prefix aliasing + copy-on-write divergence across sharded pools: the
    COW page copy runs per-shard (pallas) / partitioned (reference) and the
    diverged request still matches single-device."""
    cfg = _dense_cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(21)
    shared = rng.integers(0, cfg.vocab_size, size=(24,)).astype(np.int32)
    prompts = [
        np.concatenate([shared, rng.integers(0, cfg.vocab_size, size=(3,))
                        ]).astype(np.int32),
        shared[:20].copy(),  # partial tail page -> COW divergence
    ]
    ec = EngineConfig(max_seqs=2, max_len=48, page_size=8)
    _, base = _run_engine(cfg, params, prompts, 8, ec, stagger=4)
    eng, out = _run_engine(
        cfg, params, prompts, 8, ec, mesh=make_serve_mesh("1x4"), stagger=4
    )
    assert eng.kv.cow_copies >= 1 and eng.kv.pages_aliased >= 1
    for b, o in zip(base, out):
        np.testing.assert_array_equal(o, b)


def test_mesh_rejects_nondividing_kv_heads():
    """Satellite fix: EngineConfig validation fails at construction — with
    an actionable message — when the paged kv-head axis cannot divide the
    mesh's model axis, instead of silently replicating every pool."""
    cfg = dataclasses.replace(
        C.get_config("minicpm-2b", smoke=True, dtype=jnp.float32), block=8
    )  # stock heads: 6 % 4 != 0
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="n_kv_heads=6.*model-axis size 4"):
        Engine(cfg, params, EngineConfig(max_seqs=2, max_len=32, page_size=8),
               mesh=make_serve_mesh("1x4"))
    # a dividing mesh constructs fine
    Engine(cfg, params, EngineConfig(max_seqs=2, max_len=32, page_size=8),
           mesh=make_serve_mesh("1x2"))

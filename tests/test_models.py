"""Per-architecture smoke tests (reduced configs) + family-level math checks.

Every assigned arch: instantiate the smoke config, run one train step on CPU,
assert output shapes and no NaNs; run prefill + a decode step and check
decode-vs-full-forward consistency where cheap.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models import ssm as ssmm


def _batch(cfg, B=2, S=32, key=1):
    toks = jax.random.randint(jax.random.PRNGKey(key), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    if cfg.frontend == "vision":
        batch["vis_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_frontend_tokens, cfg.d_model)
        ).astype(cfg.dtype)
        batch["positions3"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, S)
        )
    if cfg.frontend == "audio":
        batch["audio_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.encoder_seq, cfg.d_model)
        ).astype(cfg.dtype)
    return batch


# Every arch's train smoke runs in tier-1.  The MoE/MTP archs
# (deepseek-v3, granite-moe: capacity-dispatch grad graphs; qwen2-vl:
# M-RoPE + vision prefix) used to live behind -m slow for compile time —
# promoted back once CI grew a persistent JAX compilation cache (warm runs
# skip the compile; cold costs measured 2026-07: deepseek ~32 s, qwen2-vl
# ~13 s, granite ~11 s).
ARCH_TRAIN_PARAMS = list(C.arch_ids())


@pytest.mark.parametrize("arch", ARCH_TRAIN_PARAMS)
def test_arch_smoke_train_step(arch):
    cfg = C.get_config(arch, smoke=True, dtype=jnp.float32)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux, h = M.forward_train(cfg, params, batch, remat=False)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, metrics = M.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))
    # one gradient step moves the loss
    g = jax.grad(lambda p: M.loss_fn(cfg, p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", C.arch_ids())
def test_arch_smoke_prefill_decode(arch):
    cfg = C.get_config(arch, smoke=True, dtype=jnp.float32)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    pb = {k: v for k, v in batch.items() if k != "labels"}
    logits, caches = M.prefill(cfg, params, pb)
    assert logits.shape[0] == 2 and logits.shape[1] == 1
    full = M.init_cache(cfg, 2, 40)

    def fit(a, b):
        if a.shape == b.shape:
            return a.astype(b.dtype)
        return jax.lax.dynamic_update_slice(b, a.astype(b.dtype), (0,) * b.ndim)

    caches = jax.tree.map(fit, caches, full)
    lg, caches2 = M.decode_step(
        cfg, params, caches, pb["tokens"][:, -1:], jnp.int32(32)
    )
    assert np.isfinite(np.asarray(lg, np.float32)).all()


def test_dense_decode_matches_forward():
    cfg = C.get_config("minicpm-2b", smoke=True, dtype=jnp.float32)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    logits_full, _, _ = M.forward_train(
        cfg, params, {"tokens": toks, "labels": toks}, remat=False
    )
    lg, caches = M.prefill(cfg, params, {"tokens": toks[:, :16]})
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(logits_full[:, 15]), rtol=2e-3, atol=2e-3
    )
    full = M.init_cache(cfg, B, S)
    caches = jax.tree.map(
        lambda a, b: a if a.shape == b.shape
        else jax.lax.dynamic_update_slice(b, a.astype(b.dtype), (0,) * b.ndim),
        caches, full,
    )
    for t in range(16, 20):
        lg, caches = M.decode_step(cfg, params, caches, toks[:, t:t+1], jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(logits_full[:, t]),
            rtol=3e-2, atol=3e-2,
        )


def test_swa_ring_buffer_decode():
    """SWA decode past the window: ring buffer must keep only live tokens."""
    cfg = C.get_config("h2o-danube-3-4b", smoke=True, dtype=jnp.float32)
    assert cfg.attn_type == "swa" and cfg.window == 8  # repro: noqa RPR004 -- asserts the fixture config, no dispatch
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    logits_full, _, _ = M.forward_train(
        cfg, params, {"tokens": toks, "labels": toks}, remat=False
    )
    lg, caches = M.prefill(cfg, params, {"tokens": toks[:, :16]})
    full = M.init_cache(cfg, B, S)
    caches = jax.tree.map(
        lambda a, b: a if a.shape == b.shape
        else jax.lax.dynamic_update_slice(b, a.astype(b.dtype), (0,) * b.ndim),
        caches, full,
    )
    for t in range(16, 22):  # decoding well past the 8-token window
        lg, caches = M.decode_step(cfg, params, caches, toks[:, t:t+1], jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(logits_full[:, t]),
            rtol=3e-2, atol=3e-2,
        )


def test_ssd_chunked_vs_recurrence():
    cfg = ModelConfig(
        name="t", family="ssm", n_layers=1, d_model=32, n_heads=0,
        n_kv_heads=0, d_head=0, d_ff=0, vocab_size=16,
        ssm_state=16, ssm_headdim=8, ssm_expand=2, ssm_ngroups=2,
        ssm_chunk=8, dtype=jnp.float32,
    )
    p = ssmm.ssm_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32)) * 0.5
    y_chunk, _ = ssmm.ssm_forward(p, cfg, x)
    y_ref = ssmm.ssm_reference(p, cfg, x)
    np.testing.assert_allclose(
        np.asarray(y_chunk), np.asarray(y_ref), rtol=3e-4, atol=3e-4
    )


def test_mamba_decode_matches_forward():
    cfg = C.get_config("mamba2-130m", smoke=True, dtype=jnp.float32)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    logits_full, _, _ = M.forward_train(
        cfg, params, {"tokens": toks, "labels": toks}, remat=False
    )
    lg, caches = M.prefill(cfg, params, {"tokens": toks[:, :8]})
    for t in range(8, 12):
        lg, caches = M.decode_step(cfg, params, caches, toks[:, t:t+1], jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(logits_full[:, t]),
            rtol=3e-2, atol=3e-2,
        )


def test_moe_routes_to_topk_experts_only():
    """Capacity-dispatch invariant: disabling all but the chosen experts'
    weights must not change the output."""
    from repro.models import ffn as ffnm
    cfg = C.get_config("granite-moe-3b-a800m", smoke=True, dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    p = ffnm.moe_init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    out, aux = ffnm.moe_forward(p, cfg, x)
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) > 0  # aux loss active
    # gate weights sum to 1 across chosen experts -> scaling all expert
    # outputs by 2 scales the routed component by 2
    p2 = dict(p)
    p2["w_down"] = p["w_down"] * 2
    out2, _ = ffnm.moe_forward(p2, cfg, x)
    shared = ffnm.ffn_forward(p["shared"], cfg, x.reshape(-1, cfg.d_model)).reshape(x.shape) if "shared" in p else 0
    np.testing.assert_allclose(
        np.asarray(out2 - shared), np.asarray((out - shared) * 2),
        rtol=1e-4, atol=1e-5,
    )


def test_param_counts_match_published():
    expect = {
        "qwen2-vl-72b": 72e9, "deepseek-v3-671b": 671e9, "qwen1.5-110b": 111e9,
        "starcoder2-7b": 7.2e9, "minicpm-2b": 2.7e9, "h2o-danube-3-4b": 4.0e9,
        "granite-moe-3b-a800m": 3.3e9, "mamba2-130m": 0.13e9,
    }
    for arch, n in expect.items():
        got = C.get_config(arch).param_count()
        assert abs(got - n) / n < 0.12, (arch, got, n)


def test_gemm_backend_bwma_matches_xla():
    """The paper's layout policy as a model switch: identical numerics."""
    import dataclasses
    cfg = C.get_config("minicpm-2b", smoke=True, dtype=jnp.float32,
                       n_layers=1, d_model=64, n_heads=4, n_kv_heads=4,
                       d_head=16, d_ff=128, vocab_size=128, block=16)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 128)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    lx, _, _ = M.forward_train(cfg, params, batch, remat=False)
    for backend in ("bwma", "rwma"):
        cfgb = dataclasses.replace(cfg, gemm_backend=backend)
        lb, _, _ = M.forward_train(cfgb, params, batch, remat=False)
        np.testing.assert_allclose(np.asarray(lb), np.asarray(lx),
                                   rtol=2e-4, atol=2e-4)

"""Observability tests: metrics registry units, lifecycle-span correctness
under preemption/resume and slot re-fill, derived-stats consistency, and
the Chrome-trace export/validation contract.

The load-bearing guarantees:

* spans nest and close exactly — a drained engine leaves no open span, a
  mid-prefill preemption closes the victim's chunk/prefill spans (marked
  ``preempted``) and the resume opens fresh ones (no orphans);
* observability is free of observable effect — greedy outputs and the
  deterministic metrics (counters, step-unit histograms) are bit-identical
  between ``obs=True`` and ``obs=False`` engines.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import model as M
from repro.serve import (
    Engine,
    EngineConfig,
    MetricsRegistry,
    RequestTimeline,
    build_serve_report,
    validate_chrome_trace,
)
from repro.serve.obs import Histogram, main as obs_main
from repro.serve.scheduler import Request, RequestStats


def _paged_cfg(**over):
    cfg = C.get_config("minicpm-2b", smoke=True, dtype=jnp.float32)
    return dataclasses.replace(cfg, **over)


@pytest.fixture(scope="module")
def cfg4():
    return _paged_cfg(block=4)


@pytest.fixture(scope="module")
def params4(cfg4):
    return M.init_params(cfg4, jax.random.PRNGKey(0))


# --------------------------------------------------------------------------
# Metrics registry units
# --------------------------------------------------------------------------

def test_registry_get_or_create_and_snapshot():
    r = MetricsRegistry()
    c = r.counter("reqs", "requests")
    c.inc()
    c.inc(4)
    assert r.counter("reqs") is c and c.value == 5
    g = r.gauge("depth")
    g.set(3)
    g.set(1)
    assert r.gauge("depth") is g and g.value == 1
    h = r.histogram("occ", edges=(1, 2, 4))
    for v in (0, 1, 2, 3, 9):
        h.observe(v)
    assert r.histogram("occ") is h
    # buckets are inclusive upper bounds + overflow: [<=1, <=2, <=4, >4]
    assert h.counts == [2, 1, 1, 1] and h.count == 5 and h.sum == 15
    snap = r.snapshot()
    assert snap["counters"] == {"reqs": 5}
    assert snap["gauges"] == {"depth": 1}
    assert snap["histograms"]["occ"]["edges"] == [1, 2, 4]
    json.dumps(snap)  # snapshot must be JSON-clean as-is


def test_histogram_unsorted_edges_sorted():
    h = Histogram("h", edges=(8, 1, 4))
    assert h.edges == (1, 4, 8)
    h.observe(5)
    assert h.counts == [0, 0, 1, 0]


# --------------------------------------------------------------------------
# Timeline / span units
# --------------------------------------------------------------------------

def test_timeline_span_discipline():
    tl = RequestTimeline()
    tl.begin("queued", 0, 0.0)
    with pytest.raises(AssertionError):
        tl.begin("queued", 1, 1.0)  # double-open is a bug, loudly
    s = tl.end("queued", 3, 3.0)
    assert s.steps == 3 and s.wall_s == 3.0 and not s.open
    tl.begin("prefill", 3, 3.0)
    tl.begin("prefill-chunk", 3, 3.0)
    closed = tl.close_all(5, 5.0, preempted=True)
    assert {c.name for c in closed} == {"prefill", "prefill-chunk"}
    assert all(c.attrs["preempted"] for c in closed)
    assert tl.open_spans == []
    with pytest.raises(KeyError):
        tl.end("prefill", 6, 6.0)  # closing a closed span is a bug too
    assert tl.mark("first_token", 7, 7.0)
    assert not tl.mark("first_token", 9, 9.0)  # milestones are first-only
    assert tl.marks["first_token"] == (7, 7.0)


def test_derived_stats_defaults_match_legacy():
    """A fresh Request's derived stats expose the pre-span defaults the
    drivers/benchmarks relied on (arrival 0, the rest -1 / 0.0)."""
    req = Request(rid=0, prompt=np.zeros(4, np.int32), max_new_tokens=2)
    s = req.stats
    assert isinstance(s, RequestStats)
    assert s.arrival_step == 0 and s.admitted_step == -1
    assert s.first_token_step == -1 and s.finish_step == -1
    assert s.t_arrival == 0.0 and s.t_finish == 0.0
    assert s.n_preemptions == 0 and s.cached_prompt_tokens == 0
    assert s.decode_tok_s(1) == float("inf")


def test_stats_single_source_for_step_and_wall():
    """The bugfix: step- and wall-TTFT must read the SAME milestones."""
    tl = RequestTimeline()
    tl.mark("arrival", 2, 10.0)
    tl.mark("admitted", 4, 10.5)
    tl.mark("first_token", 7, 11.0)
    tl.mark("finish", 9, 12.0)
    s = RequestStats(tl)
    assert s.queue_steps == 2 and s.ttft_steps == 5
    assert s.ttft_s == pytest.approx(1.0)
    assert s.decode_tok_s(3) == pytest.approx(2.0)


# --------------------------------------------------------------------------
# Engine integration: spans under preemption/resume + slot re-fill
# --------------------------------------------------------------------------

def _drive(eng):
    for _ in range(500):
        if not eng.sched.has_work():
            break
        eng.step()
    eng._flush_pending()
    assert not eng.sched.has_work()


def test_spans_close_exactly_under_mid_prefill_preemption(cfg4, params4):
    """The test_serve mid-prefill preemption workload, checked for span
    discipline: the victim's chunk/prefill spans close at preemption
    (no orphans), the resume opens fresh ones, and a drained engine leaves
    every span on every request closed."""
    rng = np.random.default_rng(11)
    short = rng.integers(0, cfg4.vocab_size, size=(8,)).astype(np.int32)
    long = rng.integers(0, cfg4.vocab_size, size=(16,)).astype(np.int32)
    eng = Engine(cfg4, params4, EngineConfig(
        max_seqs=2, max_len=24, page_size=4, num_pages=9,
        prefill_tokens_per_step=4,
    ))
    a = eng.submit(short, 8, rid=0)
    b = eng.submit(long, 8, rid=1)
    _drive(eng)
    assert b.stats.n_preemptions >= 1, "workload must exercise preemption"

    for req in (a, b):
        tl = req.timeline
        assert tl.open_spans == [], f"rid {req.rid} left spans open"
        assert all(not s.open and s.end_step >= s.begin_step for s in tl.spans)
        # milestones complete and ordered
        s = req.stats
        assert (s.arrival_step <= s.admitted_step <= s.first_token_step
                <= s.finish_step)
        # chunk spans nest inside a prefill span's interval
        prefills = [s for s in tl.spans if s.name == "prefill"]
        for ch in (s for s in tl.spans if s.name == "prefill-chunk"):
            assert any(p.begin_step <= ch.begin_step
                       and ch.end_step <= p.end_step for p in prefills), (
                f"rid {req.rid}: orphan prefill-chunk span {ch}"
            )

    # the victim's structure: each preemption closes one span generation
    # with preempted=True and re-opens "queued"; every re-admission opens a
    # fresh prefill; a preemption that lands after the first token closes
    # the decode span and the next prefill completion re-opens it
    tlb = b.timeline
    n_pre = tlb.n_preemptions
    queued = [s for s in tlb.spans if s.name == "queued"]
    prefills = [s for s in tlb.spans if s.name == "prefill"]
    decodes = [s for s in tlb.spans if s.name == "decode"]
    assert len(queued) == n_pre + 1
    assert len(prefills) == n_pre + 1
    assert sum(1 for s in tlb.spans if s.attrs.get("preempted")) >= n_pre
    assert len(decodes) == 1 + sum(
        1 for s in decodes if s.attrs.get("preempted")
    )
    assert not decodes[-1].attrs.get("preempted")  # the finishing one
    assert [n for n, *_ in tlb.instants] == ["preempt"] * n_pre
    # chunk spans from the aborted prefill closed AT the preemption, and
    # the resumed prefill re-ran its chunks from scratch
    total_chunk_tokens = sum(
        s.attrs["tokens"] for s in tlb.spans
        if s.name == "prefill-chunk" and not s.attrs.get("preempted")
    )
    assert total_chunk_tokens >= len(long)
    # registry counters saw the same story
    counters = eng.metrics()["counters"]
    assert counters["preemptions_total"] == n_pre
    assert counters["admissions_total"] == 2 + n_pre
    assert counters["finished_total"] == 2


def test_slot_refill_keeps_timelines_separate(cfg4, params4):
    """More requests than slots: re-filled slots must not bleed spans
    between the old and new occupant."""
    rng = np.random.default_rng(3)
    eng = Engine(cfg4, params4, EngineConfig(max_seqs=2, max_len=40))
    reqs = [
        eng.submit(rng.integers(0, cfg4.vocab_size, size=(8,)).astype(np.int32),
                   4 + i, rid=i, arrival_step=i)
        for i in range(5)
    ]
    _drive(eng)
    for req in reqs:
        tl = req.timeline
        assert tl.open_spans == []
        assert len([s for s in tl.spans if s.name == "decode"]) == 1
        assert req.stats.finish_step >= req.stats.first_token_step
        assert eng.obs.timelines[req.rid] is tl
    counters = eng.metrics()["counters"]
    assert counters["finished_total"] == 5
    assert counters["generated_tokens_total"] == sum(
        len(r.out_tokens) for r in reqs
    )
    h = eng.metrics()["histograms"]["generated_tokens"]
    assert h["count"] == 5


# --------------------------------------------------------------------------
# obs on/off: outputs and deterministic metrics bit-identical
# --------------------------------------------------------------------------

def test_obs_on_off_outputs_and_metrics_identical(cfg4, params4):
    """Deep observability must be a pure observer: greedy outputs and all
    deterministic metrics (counters; step-unit histograms) bit-identical
    to the gated-off engine on the same preemption-heavy workload."""
    def run(obs):
        rng = np.random.default_rng(11)
        short = rng.integers(0, cfg4.vocab_size, size=(8,)).astype(np.int32)
        long = rng.integers(0, cfg4.vocab_size, size=(16,)).astype(np.int32)
        eng = Engine(cfg4, params4, EngineConfig(
            max_seqs=2, max_len=24, page_size=4, num_pages=9,
            prefill_tokens_per_step=4, obs=obs,
        ))
        eng.submit(short, 8, rid=0)
        eng.submit(long, 8, rid=1)
        done = eng.run()
        outs = {r.rid: list(r.out_tokens) for r in done}
        return outs, eng.metrics()

    outs_off, m_off = run(False)
    outs_on, m_on = run(True)
    assert outs_on == outs_off
    assert m_on["counters"] == m_off["counters"]
    assert m_on["histograms"] == m_off["histograms"]
    # gauges too — except the two audit-backed ones only deep collection
    # fills (their staying 0 when gated off is exactly the gating contract)
    deep_only = {"pages_index_pinned", "pages_slot_held"}
    for name, v in m_off["gauges"].items():
        if name not in deep_only:
            assert m_on["gauges"][name] == v, name
    assert m_off["gauges"]["pages_index_pinned"] == 0
    # deep gauges carry the drained-engine audit: every non-free page is
    # prefix-index-pinned once nothing runs
    g = m_on["gauges"]
    assert g["pages_free"] + g["pages_index_pinned"] == g["pages_total"]


# --------------------------------------------------------------------------
# Chrome-trace export + validation
# --------------------------------------------------------------------------

def test_trace_export_valid_and_loadable(cfg4, params4, tmp_path):
    rng = np.random.default_rng(0)
    eng = Engine(cfg4, params4, EngineConfig(max_seqs=2, max_len=32))
    for i in range(3):
        eng.submit(rng.integers(0, cfg4.vocab_size, size=(8,)).astype(np.int32),
                   4, rid=i, arrival_step=i)
    eng.run()
    path = tmp_path / "trace.json"
    trace = eng.export_trace(str(path))
    assert validate_chrome_trace(trace) == []
    on_disk = json.loads(path.read_text())
    assert validate_chrome_trace(on_disk) == []
    # one engine-step X event per engine step, one track per request
    engine_x = [e for e in on_disk["traceEvents"]
                if e.get("ph") == "X" and e.get("cat") == "engine"]
    assert len(engine_x) == eng.step_count
    req_tids = {e["tid"] for e in on_disk["traceEvents"]
                if e.get("ph") == "X" and e.get("cat") == "request"}
    assert len(req_tids) == 3
    # the CLI validator agrees
    assert obs_main([str(path)]) == 0


def test_trace_validator_rejects_malformed(tmp_path, capsys):
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({}) != []
    assert validate_chrome_trace({"traceEvents": []}) != []
    bad_x = {"traceEvents": [{"ph": "X", "name": "n", "ts": 0}]}
    assert any("missing" in p for p in validate_chrome_trace(bad_x))
    no_tracks = {"traceEvents": [{"ph": "M", "name": "process_name"}]}
    problems = validate_chrome_trace(no_tracks)
    assert any("engine-step track" in p for p in problems)
    assert any("request span track" in p for p in problems)
    # negative timestamps are nonsense in this exporter
    neg = {"traceEvents": [
        {"ph": "X", "name": "s", "cat": "engine", "ts": -1, "dur": 1,
         "pid": 1, "tid": 0},
        {"ph": "X", "name": "s", "cat": "request", "ts": 0, "dur": 1,
         "pid": 1, "tid": 1},
    ]}
    assert any("bad ts" in p for p in validate_chrome_trace(neg))
    # CLI: malformed file -> nonzero, problems printed
    p = tmp_path / "bad.json"
    p.write_text(json.dumps({"traceEvents": []}))
    assert obs_main([str(p)]) == 1
    assert "non-empty" in capsys.readouterr().out
    assert obs_main([str(tmp_path / "missing.json")]) == 1


def test_open_spans_export_flagged(cfg4, params4):
    """A live (undrained) engine's trace is still valid: open spans export
    with an explicit marker and a to-now duration."""
    rng = np.random.default_rng(0)
    eng = Engine(cfg4, params4, EngineConfig(max_seqs=1, max_len=32))
    eng.submit(rng.integers(0, cfg4.vocab_size, size=(8,)).astype(np.int32),
               8, rid=0)
    for _ in range(3):
        eng.step()
    trace = eng.obs.chrome_trace()
    assert validate_chrome_trace(trace) == []
    open_evs = [e for e in trace["traceEvents"]
                if e.get("args", {}).get("open")]
    assert open_evs and all(e["dur"] >= 0 for e in open_evs)
    _drive(eng)


# --------------------------------------------------------------------------
# JSON report
# --------------------------------------------------------------------------

def test_serve_report_json_clean_and_consistent(cfg4, params4):
    rng = np.random.default_rng(5)
    eng = Engine(cfg4, params4, EngineConfig(max_seqs=2, max_len=40))
    reqs = [
        eng.submit(rng.integers(0, cfg4.vocab_size, size=(8,)).astype(np.int32),
                   6, rid=i, arrival_step=2 * i)
        for i in range(4)
    ]
    done = eng.run()
    report = build_serve_report(eng, done, wall_s=1.5,
                                useful_tokens=sum(len(r.out_tokens)
                                                  for r in done))
    # standard-JSON round trip: no inf/nan anywhere
    parsed = json.loads(json.dumps(report, allow_nan=False))
    assert parsed["engine"]["steps"] == eng.step_count
    assert parsed["pool"]["pages_free"] == eng.kv.num_free_pages
    by_rid = {r["rid"]: r for r in parsed["requests"]}
    for req in reqs:
        row, s = by_rid[req.rid], req.stats
        assert row["ttft_steps"] == s.ttft_steps
        assert row["queue_steps"] == s.queue_steps
        assert row["ttft_ms"] == pytest.approx(s.ttft_s * 1e3)
        assert row["n_tokens"] == len(req.out_tokens)
    # a single-token request has inf decode_tok_s -> None in the report
    eng2 = Engine(cfg4, params4, EngineConfig(max_seqs=1, max_len=16))
    eng2.submit(rng.integers(0, cfg4.vocab_size, size=(4,)).astype(np.int32),
                1, rid=0)
    done2 = eng2.run()
    rep2 = build_serve_report(eng2, done2)
    assert rep2["requests"][0]["decode_tok_s"] is None
    json.dumps(rep2, allow_nan=False)

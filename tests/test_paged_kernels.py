"""Paged-decode kernel parity: fused Pallas kernels vs the jnp gather oracle.

Kernel-level counterpart of the engine-level backend tests in
``tests/test_serve.py``: each fused kernel (interpret mode on CPU — the
identical grids/BlockSpecs the TPU lowering uses) is swept per page count
and per ``seq_pos`` edge against the reference gather->attend functions it
replaces.  Attention parity is gated at 1e-6 (online-softmax reassociation
— the PR-1 BWMA tolerance); the COW page copy must be bit-exact.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.backend import resolve_backend
from repro.kernels.paged_attention import (
    mla_paged_attention_decode,
    paged_attention_decode,
    paged_copy,
)
from repro.models.attention import (
    mla_paged_gather_attend,
    paged_gather_attend,
)

PAGE = 8
MAXP = 4
TOL = 1e-6


def _table_and_pool(rng, B, maxp, used_pages, leaf_shapes):
    """A paged layout: per-slot table rows mapping ``used_pages`` distinct
    physical pages (page 0 is the null page, never mapped), plus random
    pool leaves.  Unused table entries point at the null page like the
    engine's reset rows."""
    num_pages = B * maxp + 1
    table = np.zeros((B, maxp), np.int32)
    phys = rng.permutation(np.arange(1, num_pages))
    k = 0
    for b in range(B):
        table[b, :used_pages] = phys[k:k + used_pages]
        k += used_pages
    pools = [
        jnp.asarray(rng.standard_normal((num_pages,) + s), jnp.float32)
        for s in leaf_shapes
    ]
    return jnp.asarray(table), pools


def _edge_positions(used_pages):
    """seq_pos edges within the last used page: page boundary start, an
    interior partial fill, and the fully-written page."""
    last = (used_pages - 1) * PAGE
    return sorted({0, last, last + PAGE // 2, used_pages * PAGE - 1})


@pytest.mark.parametrize("groups", [1, 2, 4])
@pytest.mark.parametrize("used_pages", [1, 2, 4])
def test_paged_decode_matches_gather(used_pages, groups):
    rng = np.random.default_rng(used_pages * 10 + groups)
    B, H, dh = 2, 4, 16
    hkv = H // groups
    table, (k_pages, v_pages) = _table_and_pool(
        rng, B, MAXP, used_pages, [(PAGE, hkv, dh)] * 2
    )
    q = jnp.asarray(rng.standard_normal((B, 1, H, dh)), jnp.float32)
    for pos in _edge_positions(used_pages):
        seq_pos = jnp.full((B,), pos, jnp.int32)
        ref = paged_gather_attend(q, k_pages, v_pages, table, seq_pos)
        out = paged_attention_decode(
            q, k_pages, v_pages, table, seq_pos, interpret=True
        )
        assert out.shape == ref.shape and out.dtype == ref.dtype
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err <= TOL, (used_pages, groups, pos, err)


def test_paged_decode_ragged_positions():
    """Slots at different fill levels in one batched call — each row masks
    by its own seq_pos (null pages in unused table slots stay masked)."""
    rng = np.random.default_rng(3)
    B, H, dh = 3, 4, 16
    table, (k_pages, v_pages) = _table_and_pool(
        rng, B, MAXP, MAXP, [(PAGE, 2, dh)] * 2
    )
    q = jnp.asarray(rng.standard_normal((B, 1, H, dh)), jnp.float32)
    seq_pos = jnp.asarray([0, PAGE - 1, MAXP * PAGE - 1], jnp.int32)
    ref = paged_gather_attend(q, k_pages, v_pages, table, seq_pos)
    out = paged_attention_decode(
        q, k_pages, v_pages, table, seq_pos, interpret=True
    )
    assert float(jnp.max(jnp.abs(out - ref))) <= TOL


@pytest.mark.parametrize("used_pages", [1, 2, 4])
def test_mla_paged_decode_matches_gather(used_pages):
    rng = np.random.default_rng(used_pages)
    B, H, r, dr = 2, 4, 16, 8
    scale = (24 + dr) ** -0.5  # absorbed qk_nope + rope dims, as in MLA
    table, (ckv_pages, krope_pages) = _table_and_pool(
        rng, B, MAXP, used_pages, [(PAGE, r), (PAGE, dr)]
    )
    q_lat = jnp.asarray(rng.standard_normal((B, 1, H, r)), jnp.float32)
    q_rope = jnp.asarray(rng.standard_normal((B, 1, H, dr)), jnp.float32)
    for pos in _edge_positions(used_pages):
        seq_pos = jnp.full((B,), pos, jnp.int32)
        ref = mla_paged_gather_attend(
            q_lat, q_rope, ckv_pages, krope_pages, table, seq_pos,
            scale=scale,
        )
        out = mla_paged_attention_decode(
            q_lat, q_rope, ckv_pages, krope_pages, table, seq_pos,
            scale=scale, interpret=True,
        )
        assert out.shape == ref.shape and out.dtype == ref.dtype
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err <= TOL, (used_pages, pos, err)


def test_paged_copy_bit_exact():
    """The COW kernel is a pure data movement: dst page becomes src page
    bit-for-bit, every other page untouched, dtype preserved."""
    rng = np.random.default_rng(7)
    pool = jnp.asarray(
        rng.standard_normal((3, 5, PAGE, 2, 6)), jnp.float32
    )
    out = paged_copy(pool, 1, 3, interpret=True)
    expect = pool.at[:, 3].set(pool[:, 1])
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))
    assert out.dtype == pool.dtype


def test_paged_copy_survives_donating_jit():
    """Inside a donating jit — the engine's COW step shape — the aliased
    pool update stays bit-exact (and the alias is what jaxcheck RPJ101
    budgets; here we only pin numerics)."""
    rng = np.random.default_rng(8)
    pool = jnp.asarray(rng.standard_normal((2, 4, PAGE, 3)), jnp.float32)
    expect = pool.at[:, 2].set(pool[:, 1])

    @functools.partial(jax.jit, donate_argnums=(0,))
    def step(p, s, d):
        return paged_copy(p, s, d, interpret=True)

    out = step(pool, jnp.int32(1), jnp.int32(2))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_backend_dispatch_parity():
    """The Backend protocol surface: reference and pallas backends agree on
    all three paged operators, including the dict-of-pools COW copy."""
    rng = np.random.default_rng(11)
    B, H, dh = 2, 4, 16
    table, (k_pages, v_pages) = _table_and_pool(
        rng, B, MAXP, 2, [(PAGE, 2, dh)] * 2
    )
    q = jnp.asarray(rng.standard_normal((B, 1, H, dh)), jnp.float32)
    seq_pos = jnp.asarray([5, 13], jnp.int32)
    ref_be = resolve_backend("reference")
    pal_be = resolve_backend("pallas")  # interpret auto-resolves off-TPU
    a = ref_be.paged_attention_decode(q, k_pages, v_pages, table, seq_pos)
    b = pal_be.paged_attention_decode(q, k_pages, v_pages, table, seq_pos)
    assert float(jnp.max(jnp.abs(a - b))) <= TOL
    # layer-stacked pools, page axis 1 — the adapters' COW layout
    pools = {"k_pages": k_pages[None].repeat(2, 0),
             "v_pages": v_pages[None].repeat(2, 0)}
    got = pal_be.paged_copy_page(pools, 1, 2)
    want = ref_be.paged_copy_page(pools, 1, 2)
    assert set(got) == set(want)
    for name in got:
        np.testing.assert_array_equal(np.asarray(got[name]),
                                      np.asarray(want[name]))

"""Runtime sanitizer harness for the serving stack (opt-in: ``--sanitize``).

The static half of the correctness backstop is ``repro.analysis.staticcheck``
(rules RPR001-RPR005); this file is the runtime half, enforcing the same
invariants on a live engine:

* **transfer guard** — the jitted decode/chunk steps run under
  ``jax.transfer_guard("disallow")``: any implicit device<->host transfer
  inside the hot loop fails the test (the deferred-sync design means the
  only sanctioned syncs happen *outside* the guarded calls);
* **tracer leaks** — the chunked-prefill path runs under
  ``jax.check_tracer_leaks()``;
* **retrace budget** — the ``retrace_budget`` fixture asserts
  ``_cache_size()`` compile counts stay within the declared budget;
* **refcount audit** — ``EngineConfig(debug_audit=True)`` cross-checks the
  page-pool accounting (free + index-pinned + slot-held == total) after
  every engine step.

All tests here are skipped unless pytest runs with ``--sanitize`` (CI runs
them as a dedicated smoke job on the dense family).
"""

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import model as M
from repro.serve import Engine, EngineConfig, engine as E

pytestmark = pytest.mark.sanitize


@pytest.fixture(autouse=True)
def _sanitize_only(request):
    if not request.config.getoption("--sanitize"):
        pytest.skip("runtime sanitizers disabled (enable with pytest --sanitize)")


def _cfg(**over):
    cfg = C.get_config("minicpm-2b", smoke=True, dtype=jnp.float32)
    return dataclasses.replace(cfg, **over)


def _prompts(cfg, lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
        for n in lengths
    ]


def _guarded(fn):
    """Run a jitted callable under a disallow-everything transfer guard.

    Python evaluates the argument expressions *before* the wrapper body, so
    explicit host->device staging at the call sites (``jnp.asarray(toks)``,
    the dirty-tracked page-table upload) stays legal while the jitted step
    itself must be transfer-free.
    """

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        with jax.transfer_guard("disallow"):
            return fn(*args, **kwargs)

    return wrapped


def test_engine_steps_run_under_transfer_guard():
    """Chunked prefill + paged decode with every jitted step transfer-
    guarded: outputs must match the unguarded engine exactly, proving the
    hot loop's only host syncs are the sanctioned deferred ones."""
    cfg = _cfg(block=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts = _prompts(cfg, (12, 9, 14))
    ref = Engine(cfg, params, EngineConfig(max_seqs=2, max_len=32, page_size=8))
    for i, p in enumerate(prompts):
        ref.submit(p, 8, rid=i, arrival_step=i)
    ref_out = [np.asarray(r.out_tokens) for r in ref.run()]

    eng = Engine(cfg, params, EngineConfig(max_seqs=2, max_len=32, page_size=8))
    eng._decode = _guarded(eng._decode)
    eng._chunk_fn = _guarded(eng._chunk_fn)
    for i, p in enumerate(prompts):
        eng.submit(p, 8, rid=i, arrival_step=i)
    reqs = eng.run()
    assert len(reqs) == 3 and all(r.state == "finished" for r in reqs)
    for r, b in zip(reqs, ref_out):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), b)


def test_chunked_prefill_no_tracer_leaks():
    """The whole chunked-prefill + decode drive traced under
    jax.check_tracer_leaks (block=4 geometry keeps the memoized jits cold,
    so tracing actually happens inside the context)."""
    cfg = _cfg(block=4)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    with jax.check_tracer_leaks():
        eng = Engine(
            cfg, params, EngineConfig(max_seqs=2, max_len=24, page_size=4)
        )
        for i, p in enumerate(_prompts(cfg, (10, 7, 11), seed=1)):
            eng.submit(p, 4, rid=i)
        reqs = eng.run()
    assert len(reqs) == 3 and all(r.state == "finished" for r in reqs)


def test_engine_retrace_budget(retrace_budget):
    """20 distinct prompt lengths through fresh jit instances: the chunk
    step may compile one full-chunk shape plus the bucketed final-chunk
    set; the decode step has exactly one shape."""
    cfg = _cfg(block=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, EngineConfig(max_seqs=2, max_len=80, page_size=8))
    # fresh jits: the memoized ones are shared across engines/tests and
    # would pollute the entry counts
    eng._chunk_fn = jax.jit(
        functools.partial(M.prefill_chunk, cfg), donate_argnums=(1,)
    )
    eng._decode = jax.jit(
        functools.partial(E._paged_step, cfg), donate_argnums=(1,)
    )
    retrace_budget.track(
        eng._chunk_fn, 1 + int(math.log2(eng.chunk_size)) + 1, "prefill_chunk"
    )
    retrace_budget.track(eng._decode, 1, "paged_decode")
    rng = np.random.default_rng(9)
    for i, n in enumerate(range(1, 41, 2)):
        eng.submit(
            rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32), 2, rid=i
        )
    eng.run()


def test_obs_enabled_engine_under_transfer_guard():
    """Deep observability adds NO hot-loop host syncs: the obs=True engine
    runs with its jitted steps transfer-guarded (recording is host-int
    bookkeeping at existing sync points) and its greedy outputs match the
    gated-off engine bit for bit."""
    cfg = _cfg(block=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts = _prompts(cfg, (12, 9, 14))
    ref = Engine(cfg, params, EngineConfig(max_seqs=2, max_len=32, page_size=8))
    for i, p in enumerate(prompts):
        ref.submit(p, 8, rid=i, arrival_step=i)
    ref_out = [np.asarray(r.out_tokens) for r in ref.run()]

    eng = Engine(cfg, params, EngineConfig(
        max_seqs=2, max_len=32, page_size=8, obs=True,
    ))
    eng._decode = _guarded(eng._decode)
    eng._chunk_fn = _guarded(eng._chunk_fn)
    for i, p in enumerate(prompts):
        eng.submit(p, 8, rid=i, arrival_step=i)
    reqs = eng.run()
    for r, b in zip(reqs, ref_out):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), b)
    # deep collection really ran: per-step audit gauges + engine-step spans
    g = eng.metrics()["gauges"]
    assert g["pages_free"] + g["pages_index_pinned"] == g["pages_total"]
    assert len(eng.obs.step_spans) == eng.step_count
    assert all(r.timeline.open_spans == [] for r in reqs)


def test_debug_audit_runs_every_step():
    """A shared-prefix + slot-refill + growth workload with
    ``debug_audit=True``: the refcount auditor cross-checks the allocator
    after every engine step, and the drained pool balances exactly."""
    cfg = _cfg(block=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts = _prompts(cfg, (16, 9, 14))
    prompts[2][:8] = prompts[0][:8]  # page-aligned shared prefix
    eng = Engine(
        cfg,
        params,
        EngineConfig(max_seqs=2, max_len=32, page_size=8, debug_audit=True),
    )
    for i, p in enumerate(prompts):
        eng.submit(p, 8, rid=i, arrival_step=i)
    reqs = eng.run()
    assert len(reqs) == 3 and all(r.state == "finished" for r in reqs)
    stats = eng.kv.audit()
    assert stats.slot_held == 0
    assert stats.free + stats.index_pinned == stats.total

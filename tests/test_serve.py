"""Serving subsystem tests: paged KV cache units + continuous-batching parity.

The headline guarantee: continuous batching is a *scheduling* change, not a
*numerics* change — every request's greedy output is bit-identical to the
single-request static-wave baseline, across the dense/GQA (paged), SWA
(ring) and SSM (state) cache families, including slot re-fill and
preemption-with-recompute.
"""
import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import model as M
from repro.serve import (
    Engine,
    EngineConfig,
    PageAllocator,
    PagedCacheConfig,
    PagedKVCache,
    ServeConfig,
    Server,
    bucket_tokens,
    make_requests,
)


# --------------------------------------------------------------------------
# Page allocator / cache manager units
# --------------------------------------------------------------------------

def test_page_allocator_refcount_cycle():
    a = PageAllocator(8)  # 7 usable pages (page 0 reserved)
    assert a.num_free == 7
    got = a.alloc(3)
    assert len(got) == 3 and a.num_free == 4
    assert 0 not in got  # null page never handed out
    assert all(a.refcount(p) == 1 for p in got)
    assert a.alloc(5) is None  # short pool: no partial allocation
    assert a.num_free == 4  # failed alloc left the pool untouched
    # aliasing: a second reference keeps the page out of the free list
    a.ref(got[:1])
    assert a.refcount(got[0]) == 2
    assert a.unref(got) == got[1:]  # first page survives its extra ref
    assert a.num_free == 6
    assert a.unref(got[:1]) == got[:1]  # last reference drops -> freed
    assert a.num_free == 7
    with pytest.raises(ValueError):
        a.unref([0])  # null page is never tracked
    with pytest.raises(ValueError):
        a.ref([got[0]])  # cannot alias a free page
    got2 = a.alloc(1)
    a.unref(got2)
    with pytest.raises(ValueError):
        a.unref(got2)  # double free


def _paged_cfg(**over):
    cfg = C.get_config("minicpm-2b", smoke=True, dtype=jnp.float32)
    return dataclasses.replace(cfg, **over)


def _idle_pages(kv):
    """Pages owned by no request: the free list plus the prefix cache.

    A drained engine must account for every page — with sharing on,
    finished prompts deliberately leave their full pages pinned in the
    prefix index (one index-owned reference each), so 'no leaks' means
    free + index-pinned == total and no slot holds references.  The full
    refcount cross-check lives in the shared auditor
    (:meth:`PagedKVCache.audit`); this helper only adds the drained-engine
    requirement that no slot holds pages."""
    stats = kv.audit()
    assert stats.slot_held == 0 and not kv._pages, (
        f"slots still hold pages: {kv._pages}"
    )
    return stats.free + stats.index_pinned


def test_kvcache_page_size_derived_from_kernel_block():
    cfg = _paged_cfg(block=16)
    kv = PagedKVCache(cfg, PagedCacheConfig(max_seqs=2, max_len=32))
    assert kv.page_size == cfg.block == 16
    # explicit override still honored
    kv2 = PagedKVCache(cfg, PagedCacheConfig(max_seqs=2, max_len=32, page_size=8))
    assert kv2.page_size == 8 and kv2.max_pages_per_seq == 4


def test_kvcache_admission_accounting():
    cfg = _paged_cfg(block=4)
    # pool of 5 usable pages, 4-token pages
    kv = PagedKVCache(cfg, PagedCacheConfig(max_seqs=2, max_len=16, num_pages=6))
    assert kv.pages_for(1) == 1 and kv.pages_for(4) == 1 and kv.pages_for(5) == 2
    assert kv.can_admit(10)  # needs ceil(11/4) = 3 <= 5
    assert kv.admit(0, 10) is not None
    assert kv.num_free_pages == 2
    assert not kv.can_admit(10)  # 3 > 2 remaining
    assert kv.admit(1, 10) is None  # OOM admission refused, pool untouched
    assert kv.num_free_pages == 2
    # growth: slot 0 already maps positions 0..11; position 12 needs page 4
    assert kv.ensure_capacity(0, 11)
    assert kv.num_free_pages == 2  # no-op, already mapped
    assert kv.ensure_capacity(0, 12)
    assert kv.num_free_pages == 1
    kv.release(0)
    assert kv.num_free_pages == 5
    # page table row reset to the null page
    assert int(np.asarray(kv.page_table()).max()) == 0


def test_kvcache_rejects_unservable_request():
    cfg = _paged_cfg(block=4)
    kv = PagedKVCache(cfg, PagedCacheConfig(max_seqs=1, max_len=8, num_pages=3))
    assert kv.fits(8) and not kv.fits(9)  # max_len bound
    eng_cfg = C.get_config("minicpm-2b", smoke=True, dtype=jnp.float32)
    params = M.init_params(eng_cfg, jax.random.PRNGKey(0))
    eng = Engine(eng_cfg, params, EngineConfig(max_seqs=1, max_len=8, page_size=4))
    with pytest.raises(ValueError):
        eng.submit(np.zeros(6, np.int32), 8)  # 14 tokens can never fit


def test_engine_rejects_unsupported_family():
    """Only the vision frontend is left outside the adapter registry, and
    the refusal must list exactly the families the registry reports."""
    from repro.models import adapters as A

    cfg = C.get_config("qwen2-vl-72b", smoke=True, dtype=jnp.float32)
    with pytest.raises(NotImplementedError) as ei:
        PagedKVCache(cfg, PagedCacheConfig())
    for family in A.supported_families():
        assert family in str(ei.value)


def test_adapter_registry_covers_all_other_archs():
    """Every arch except the vision frontend resolves to adapters."""
    from repro.models import adapters as A

    for arch in C.arch_ids():
        cfg = C.get_config(arch, smoke=True, dtype=jnp.float32)
        reason = A.unsupported_reason(cfg)
        if arch == "qwen2-vl-72b":
            assert reason is not None
        else:
            assert reason is None, (arch, reason)
            assert A.all_adapters(cfg)  # at least one family adapter


def test_adapter_chunk_grid():
    """SSM segments force prefill chunks onto the SSD chunk grid."""
    from repro.models import adapters as A

    assert A.prefill_chunk_multiple(
        C.get_config("minicpm-2b", smoke=True)) == 1
    mamba = C.get_config("mamba2-130m", smoke=True)
    assert A.prefill_chunk_multiple(mamba) == mamba.ssm_chunk


# --------------------------------------------------------------------------
# Continuous batching == single-request greedy baseline (bit-identical)
# --------------------------------------------------------------------------

def _single_request_baseline(cfg, params, prompts, max_new):
    srv = Server(cfg, params, ServeConfig(max_len=64))
    return [
        srv.generate({"tokens": jnp.asarray(p)[None]}, max_new)[0]
        for p in prompts
    ]


@pytest.mark.parametrize("arch", [
    "minicpm-2b",        # dense MHA -> block-paged cache
    "h2o-danube-3-4b",   # SWA + GQA -> per-slot ring buffer
    "mamba2-130m",       # SSM       -> per-slot O(1) state
    pytest.param("hymba-1.5b", marks=pytest.mark.slow),  # hybrid ring+state
])
def test_continuous_batching_matches_single_request(arch):
    """3 requests through 2 slots (forcing a slot re-fill): every request's
    greedy tokens must equal its single-request generate() exactly."""
    cfg = C.get_config(arch, smoke=True, dtype=jnp.float32)
    cfg = dataclasses.replace(cfg, block=8)  # page = kernel block = 8 tokens
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
        for n in (12, 9, 14)
    ]
    max_new = 8
    base = _single_request_baseline(cfg, params, prompts, max_new)
    eng = Engine(cfg, params, EngineConfig(max_seqs=2, max_len=32, page_size=8))
    for i, p in enumerate(prompts):
        eng.submit(p, max_new, rid=i, arrival_step=2 * i)  # staggered
    reqs = eng.run()
    assert len(reqs) == 3 and all(r.state == "finished" for r in reqs)
    for r, b in zip(reqs, base):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), b)
    # the third request re-filled a slot vacated by an earlier one
    assert _idle_pages(eng.kv) == eng.kv.allocator.num_pages - 1


def test_preemption_recompute_preserves_outputs():
    """A pool too small for all growth preempts LIFO; the preempted request
    re-prefills (prompt + generated) and still matches the baseline."""
    cfg = _paged_cfg(block=4)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=(10,)).astype(np.int32)
               for _ in range(3)]
    max_new = 10
    base = _single_request_baseline(cfg, params, prompts, max_new)
    # 3 requests x 5 pages full-length = 15 > 8-page pool -> forced preemption
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=3, max_len=20, page_size=4, num_pages=9,
    ))
    for i, p in enumerate(prompts):
        eng.submit(p, max_new, rid=i)
    reqs = eng.run()
    assert sum(r.stats.n_preemptions for r in reqs) >= 1
    for r, b in zip(reqs, base):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), b)
    assert _idle_pages(eng.kv) == 8  # every page accounted for


def test_oom_admission_queues_until_pages_free():
    cfg = _paged_cfg(block=4)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=(8,)).astype(np.int32)
               for _ in range(2)]
    # pool admits exactly one request at a time (3 usable pages)
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=2, max_len=12, page_size=4, num_pages=4,
    ))
    for i, p in enumerate(prompts):
        eng.submit(p, 4, rid=i)
    reqs = eng.run()
    assert all(len(r.out_tokens) == 4 for r in reqs)
    assert reqs[0].stats.queue_steps == 0
    assert reqs[1].stats.queue_steps > 0  # blocked on the page budget
    assert reqs[1].stats.admitted_step > reqs[0].stats.finish_step - 1


def test_eos_early_stop_matches_baseline_prefix():
    """The eos path disables the deferred sync (token values drive finish):
    each request must stop exactly where the single-request baseline first
    emits the eos token, keeping the prefix bit-identical."""
    cfg = _paged_cfg(block=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=(11,)).astype(np.int32)
               for _ in range(3)]
    max_new = 10
    base = _single_request_baseline(cfg, params, prompts, max_new)
    # choose an eos that actually appears mid-stream in some baseline output
    eos = int(base[0][max_new // 2])
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=2, max_len=32, page_size=8, eos_id=eos,
    ))
    for i, p in enumerate(prompts):
        eng.submit(p, max_new, rid=i)
    reqs = eng.run()
    hit_early = 0
    for r, b in zip(reqs, base):
        b = np.asarray(b)
        idx = np.flatnonzero(b == eos)
        expect = b[: idx[0] + 1] if idx.size else b
        np.testing.assert_array_equal(np.asarray(r.out_tokens), expect)
        hit_early += len(expect) < max_new
    assert hit_early >= 1  # the chosen eos truncated at least one request


def test_temperature_sampling_schedule_independent():
    """Per-request fold_in(seed, rid, position) keys: sampled outputs must
    not depend on slot count / scheduling interleave."""
    cfg = _paged_cfg(block=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, size=(9,)).astype(np.int32)
               for _ in range(3)]

    def sample_with(max_seqs):
        eng = Engine(cfg, params, EngineConfig(
            max_seqs=max_seqs, max_len=24, page_size=8,
            temperature=0.8, seed=11,
        ))
        for i, p in enumerate(prompts):
            eng.submit(p, 6, rid=i)
        return {r.rid: list(r.out_tokens) for r in eng.run()}

    serial = sample_with(1)  # fully sequential scheduling
    batched = sample_with(3)  # all three interleaved
    assert serial == batched
    # and distinct requests don't share a key stream
    assert len({tuple(v) for v in serial.values()}) > 1


def test_continuous_batching_step_efficiency():
    """Deterministic slot-step accounting: on a staggered, length-varied
    workload the continuous engine does no more decode slot-steps than the
    static wave (usually strictly fewer) for the same useful tokens."""
    cfg = C.get_config("minicpm-2b", smoke=True, dtype=jnp.float32)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    max_seqs, max_new = 2, 12
    reqs = make_requests(cfg.vocab_size, 6, prompt_len=10, max_new=max_new,
                         mean_interarrival=3.0, seed=0)
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=max_seqs, max_len=10 + max_new + 1, page_size=8,
    ))
    for r in reqs:
        eng.submit(r["prompt"], r["max_new_tokens"],
                   rid=r["rid"], arrival_step=r["arrival_step"])
    eng.run()
    continuous_slot_steps = eng.decode_steps * max_seqs
    order = sorted(reqs, key=lambda r: (r["arrival_step"], r["rid"]))
    static_slot_steps = 0
    for w in range(0, len(order), max_seqs):
        wave = order[w : w + max_seqs]
        static_slot_steps += len(wave) * max(r["max_new_tokens"] for r in wave)
    assert continuous_slot_steps <= static_slot_steps


def test_engine_reuse_and_duplicate_rids():
    """A reused engine reports only the current batch, keeps shape
    (B, max_new), and rejects duplicate request ids.

    Token values are deliberately not compared across engine instances
    here: threaded XLA CPU matmuls are not call-to-call bitwise stable, and
    this workload's random-params logits can sit on argmax near-ties — the
    numerics parity gates live in the tests above, whose workloads are
    tie-free.
    """
    cfg = _paged_cfg(block=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, EngineConfig(max_seqs=2, max_len=24, page_size=8))
    rng = np.random.default_rng(5)
    b1 = rng.integers(0, cfg.vocab_size, size=(2, 8)).astype(np.int32)
    b2 = rng.integers(0, cfg.vocab_size, size=(3, 8)).astype(np.int32)
    out1 = eng.generate({"tokens": b1}, 5)
    out2 = eng.generate({"tokens": b2}, 5)
    # only the current batch is reported, at the full (B, max_new) width
    assert out1.shape == (2, 5) and out2.shape == (3, 5)
    assert sorted(eng.sched.finished) == [0, 1, 2, 3, 4]
    # every page returned after both batches (reuse leaks nothing)
    assert _idle_pages(eng.kv) == eng.kv.allocator.num_pages - 1
    with pytest.raises(ValueError):
        eng.submit(b1[0], 4, rid=0)  # rid 0 already finished


# --------------------------------------------------------------------------
# Chunked + donating prefill
# --------------------------------------------------------------------------

def test_bucket_tokens():
    assert bucket_tokens(1, 8) == 8
    assert bucket_tokens(8, 8) == 8
    assert bucket_tokens(9, 8) == 16
    assert bucket_tokens(17, 8) == 32  # 3 pages -> 4
    assert bucket_tokens(33, 8) == 64


@pytest.mark.parametrize("arch", [
    "minicpm-2b",        # dense MHA -> paged chunk scatter + gather attention
    "h2o-danube-3-4b",   # SWA       -> ring rows carried across chunks
    "mamba2-130m",       # SSM       -> state carried on the ssm_chunk grid
    pytest.param("hymba-1.5b", marks=pytest.mark.slow),  # hybrid ring+state
])
def test_chunked_prefill_matches_unchunked(arch):
    """Chunked prefill is a *data-movement* change, not a numerics change:
    multi-chunk prompts must produce greedy outputs bit-identical to both
    the unchunked engine and single-request generate()."""
    cfg = C.get_config(arch, smoke=True, dtype=jnp.float32)
    cfg = dataclasses.replace(cfg, block=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    # lengths straddle chunk boundaries: < 1 chunk, exact multiple, ragged
    prompts = [rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
               for n in (5, 16, 19, 27)]
    max_new = 6
    base = _single_request_baseline(cfg, params, prompts, max_new)

    def run_engine(chunked):
        eng = Engine(cfg, params, EngineConfig(
            max_seqs=2, max_len=40, page_size=8, chunked_prefill=chunked,
        ))
        for i, p in enumerate(prompts):
            eng.submit(p, max_new, rid=i, arrival_step=i)
        return eng, {r.rid: list(r.out_tokens) for r in eng.run()}

    eng_c, chunked = run_engine(True)
    _, unchunked = run_engine(False)
    assert eng_c.chunk_size >= 8
    for i, b in enumerate(base):
        assert chunked[i] == list(np.asarray(b)), f"chunked != baseline (rid {i})"
        assert unchunked[i] == list(np.asarray(b)), f"unchunked != baseline (rid {i})"


def test_mid_prefill_preemption_and_resume():
    """A request preempted in the middle of its chunked prefill must restart
    cleanly on re-admission (recompute discipline) and still match the
    baseline bit for bit."""
    cfg = _paged_cfg(block=4)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    short = rng.integers(0, cfg.vocab_size, size=(8,)).astype(np.int32)
    long = rng.integers(0, cfg.vocab_size, size=(16,)).astype(np.int32)
    max_new = 8
    base = _single_request_baseline(cfg, params, [short, long], max_new)
    # pool: short needs 3 pages at admit and grows to 4; long needs 5.
    # 8 usable pages admit both, then short's growth preempts long (LIFO)
    # while long is still several chunks from its first token.
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=2, max_len=24, page_size=4, num_pages=9,
        prefill_chunks_per_step=1,
    ))
    a = eng.submit(short, max_new, rid=0)
    b = eng.submit(long, max_new, rid=1)
    saw_mid_prefill = False
    was_preempted_mid_prefill = False
    for _ in range(200):
        if not eng.sched.has_work():
            break
        prefilling_before = b.prefilling and 0 < b.prefill_pos
        eng.step()
        saw_mid_prefill |= prefilling_before
        if prefilling_before and b.state == "waiting":
            was_preempted_mid_prefill = True
    eng._flush_pending()
    assert saw_mid_prefill, "long prompt never observed mid-prefill"
    assert was_preempted_mid_prefill, "no preemption landed mid-prefill"
    assert b.stats.n_preemptions >= 1 and b.prefill_pos == b.prefill_target
    np.testing.assert_array_equal(np.asarray(a.out_tokens), base[0])
    np.testing.assert_array_equal(np.asarray(b.out_tokens), base[1])
    assert _idle_pages(eng.kv) == 8


def test_long_prompt_admission_does_not_stall_decode():
    """The point of chunked admission: while a max-length prompt works
    through its chunks, the in-flight request keeps emitting tokens every
    engine step (deterministic step accounting, no wall clock)."""
    cfg = _paged_cfg(block=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    short = rng.integers(0, cfg.vocab_size, size=(8,)).astype(np.int32)
    long = rng.integers(0, cfg.vocab_size, size=(64,)).astype(np.int32)
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=2, max_len=80, page_size=8, prefill_chunks_per_step=1,
    ))
    a = eng.submit(short, 24, rid=0, arrival_step=0)
    b = eng.submit(long, 4, rid=1, arrival_step=2)
    tokens_during_prefill = []
    for _ in range(200):
        if not eng.sched.has_work():
            break
        mid = b.prefilling
        before = a.n_generated
        eng.step()
        if mid:
            tokens_during_prefill.append(a.n_generated - before)
    eng._flush_pending()
    n_chunks = math.ceil(len(long) / eng.chunk_size)
    assert n_chunks >= 8
    # the long admission spans n_chunks engine steps...
    assert (b.stats.first_token_step - b.stats.admitted_step) >= n_chunks - 1
    # ...and the short request decoded one token in EVERY one of them
    assert len(tokens_during_prefill) >= n_chunks - 1
    assert all(n == 1 for n in tokens_during_prefill)
    # sanity: outputs still match the single-request baseline
    srv = Server(cfg, params, ServeConfig(max_len=96))
    for req, n_new in ((a, 24), (b, 4)):
        base = srv.generate(
            {"tokens": jnp.asarray(req.prompt)[None]}, n_new
        )[0]
        np.testing.assert_array_equal(np.asarray(req.out_tokens), base)


def test_server_bucketed_prefill_exact():
    """Power-of-two prompt bucketing (dense/GQA) is bit-exact: padded keys
    are masked during prefill and overwritten by decode before their
    position label becomes reachable."""
    cfg = _paged_cfg(block=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    for n in (3, 8, 11, 17, 25):
        prompt = rng.integers(0, cfg.vocab_size, size=(1, n)).astype(np.int32)
        exact = Server(cfg, params, ServeConfig(max_len=64, prefill_bucket=-1))
        bucketed = Server(cfg, params, ServeConfig(max_len=64))
        out_e = exact.generate({"tokens": jnp.asarray(prompt)}, 8)
        out_b = bucketed.generate({"tokens": jnp.asarray(prompt)}, 8)
        np.testing.assert_array_equal(out_e, out_b, err_msg=f"prompt_len={n}")


def test_prefill_jit_cache_bounded():
    """Chunked prefill must not compile per prompt length: many distinct
    lengths share one full-chunk shape + a few final-chunk shapes."""
    cfg = _paged_cfg(block=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, EngineConfig(max_seqs=2, max_len=80, page_size=8))
    # fresh jit instance: the memoized one is shared across engines with
    # different geometries, which would pollute the entry count
    eng._chunk_fn = jax.jit(
        functools.partial(M.prefill_chunk, cfg), donate_argnums=(1,)
    )
    rng = np.random.default_rng(9)
    for i, n in enumerate(range(1, 41, 2)):  # 20 distinct prompt lengths
        eng.submit(rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32),
                   2, rid=i)
    eng.run()
    # dense/GQA final chunks bucket to powers of two <= chunk size, so 20
    # lengths share at most {full chunk} + {1, 2, 4, 8} jit entries
    assert eng._chunk_fn._cache_size() <= 1 + int(math.log2(eng.chunk_size)) + 1


def test_admission_zero_pool_copy():
    """Admission must never copy the pool: the chunk step's donated cache
    pytree is updated in place (the output aliases the input buffers), and
    the compiled step allocates no pool-sized scratch."""
    cfg = _paged_cfg(block=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    # oversized pool: at production scale the pool dwarfs every activation,
    # so "no pool-sized allocation" must mean scratch stays O(activations)
    # while the pool grows — 256 usable pages makes that separation visible
    # even at smoke scale
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=2, max_len=64, page_size=8, num_pages=257,
    ))
    rng = np.random.default_rng(3)
    req = eng.submit(rng.integers(0, cfg.vocab_size, size=(20,)).astype(np.int32), 4)
    eng.sched.poll_arrivals(0)
    [(slot, _)] = eng.sched.admit(0)
    pool_before = eng.kv.data["seg0"]["attn"]["k_pages"]
    ptr_before = pool_before.unsafe_buffer_pointer()
    eng._prefill_one_chunk(slot, req)
    pool_after = eng.kv.data["seg0"]["attn"]["k_pages"]
    # donation aliased the pool: same device buffer, no copy
    assert pool_after.unsafe_buffer_pointer() == ptr_before
    with pytest.raises(RuntimeError):
        pool_before.block_until_ready()  # old reference was consumed

    # compiled-memory regression: the donated caches alias the output in
    # full (zero *persistent* pool-sized allocation per admission — the old
    # eager path allocated a fresh pool copy per layer per admission), and
    # the chunk step's scratch is no worse than the long-accepted decode
    # step's (XLA:CPU stages the scanned pool in temp for both; that is a
    # backend scan artifact, not an admission copy)
    from repro.serve.engine import _paged_step
    pool_bytes = eng.kv.cache_bytes()
    toks = jnp.zeros((1, eng.chunk_size), jnp.int32)
    phys, off = eng.kv.token_targets(slot, 0, eng.chunk_size)
    ma = jax.jit(
        functools.partial(M.prefill_chunk, cfg), donate_argnums=(1,)
    ).lower(params, eng.kv.data, toks, jnp.int32(slot), jnp.int32(0),
            phys, off, eng.kv.table_row(slot), jnp.int32(eng.chunk_size - 1)
            ).compile().memory_analysis()
    ma_dec = jax.jit(
        functools.partial(_paged_step, cfg), donate_argnums=(1,)
    ).lower(params, eng.kv.data, jnp.zeros((2, 1), jnp.int32),
            jnp.zeros((2,), jnp.int32), eng.kv.page_table(),
            jnp.ones((2,), bool)).compile().memory_analysis()
    assert ma.alias_size_in_bytes >= pool_bytes
    assert ma.output_size_in_bytes - ma.alias_size_in_bytes < pool_bytes / 8
    assert ma.temp_size_in_bytes <= 1.25 * ma_dec.temp_size_in_bytes

    # the unchunked install path donates the same way
    eng2 = Engine(cfg, params, EngineConfig(
        max_seqs=2, max_len=64, page_size=8, chunked_prefill=False,
    ))
    req2 = eng2.submit(rng.integers(0, cfg.vocab_size, size=(12,)).astype(np.int32), 2)
    eng2.sched.poll_arrivals(0)
    [(slot2, _)] = eng2.sched.admit(0)
    ptr2 = eng2.kv.data["seg0"]["attn"]["k_pages"].unsafe_buffer_pointer()
    eng2._prefill_full(slot2, req2)
    assert eng2.kv.data["seg0"]["attn"]["k_pages"].unsafe_buffer_pointer() == ptr2


# --------------------------------------------------------------------------
# MLA latent pages (CacheAdapter: LatentMLAAdapter)
# --------------------------------------------------------------------------

def _mla_dense_cfg(**over):
    """DeepSeek-shaped MLA attention over a dense FFN stack: isolates the
    latent-page adapter from the MoE capacity dispatch (whose drop pattern
    is sequence-length dependent, so *multi-chunk* MoE prefill is not
    bit-reproducible against one-shot — see the deepseek test below)."""
    cfg = C.get_config("deepseek-v3-671b", smoke=True, dtype=jnp.float32)
    over = {"block": 8, **over}
    return dataclasses.replace(
        cfg, family="dense", n_experts=0, n_shared_experts=0, top_k=0,
        moe_d_ff=0, first_k_dense=0, mtp_depth=0, d_ff=96, **over,
    )


@pytest.mark.parametrize("chunked", [True, False])
def test_mla_latent_pages_match_single_request(chunked):
    """MLA through the paged engine: latent (c_kv + k_rope) pages, absorbed-
    matmul decode — greedy outputs bit-identical to single-request
    generate(), including multi-chunk prompts and a slot re-fill."""
    cfg = _mla_dense_cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
               for n in (12, 9, 14)]
    max_new = 8
    base = _single_request_baseline(cfg, params, prompts, max_new)
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=2, max_len=32, page_size=8, chunked_prefill=chunked,
    ))
    for i, p in enumerate(prompts):
        eng.submit(p, max_new, rid=i, arrival_step=2 * i)
    reqs = eng.run()
    assert len(reqs) == 3 and all(r.state == "finished" for r in reqs)
    for r, b in zip(reqs, base):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), b)
    assert _idle_pages(eng.kv) == eng.kv.allocator.num_pages - 1
    # the latent pool really is the latent: rank + rope dims, not K/V heads
    pool = eng.kv.data["seg0"]["attn"]
    assert set(pool) == {"ckv_pages", "krope_pages"}
    assert pool["ckv_pages"].shape[-1] == cfg.kv_lora_rank


def test_mla_preemption_recompute_preserves_outputs():
    """LIFO preemption + re-prefill over latent pages stays bit-identical."""
    cfg = _mla_dense_cfg(block=4)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=(10,)).astype(np.int32)
               for _ in range(3)]
    max_new = 10
    base = _single_request_baseline(cfg, params, prompts, max_new)
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=3, max_len=20, page_size=4, num_pages=9,
    ))
    for i, p in enumerate(prompts):
        eng.submit(p, max_new, rid=i)
    reqs = eng.run()
    assert sum(r.stats.n_preemptions for r in reqs) >= 1
    for r, b in zip(reqs, base):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), b)
    assert _idle_pages(eng.kv) == 8


def test_deepseek_v3_engine_parity_single_chunk():
    """The full DeepSeek-V3 shape (MLA + MoE + MTP) through the engine.

    Prompts fit one prefill chunk: the MoE capacity dispatch then sees the
    exact one-shot token group and outputs are bit-identical (multi-chunk
    MoE prefill changes the dispatch grouping — a property of capacity
    dispatch, not of the latent-page adapter; use chunked_prefill=False
    for bitwise multi-chunk MoE serving)."""
    cfg = dataclasses.replace(
        C.get_config("deepseek-v3-671b", smoke=True, dtype=jnp.float32),
        block=8,
    )
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
               for n in (8, 7, 6)]
    max_new = 6
    base = _single_request_baseline(cfg, params, prompts, max_new)
    eng = Engine(cfg, params, EngineConfig(max_seqs=2, max_len=32, page_size=8))
    for i, p in enumerate(prompts):
        eng.submit(p, max_new, rid=i, arrival_step=i)
    reqs = eng.run()
    for r, b in zip(reqs, base):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), b)


# --------------------------------------------------------------------------
# Encoder-decoder (CacheAdapter: CrossAttnAdapter + paged self-attention)
# --------------------------------------------------------------------------

def _encdec_setup(seed=2, n_prompts=3):
    cfg = dataclasses.replace(
        C.get_config("whisper-tiny", smoke=True, dtype=jnp.float32), block=8
    )
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
               for n in (12, 9, 14)[:n_prompts]]
    embeds = [rng.normal(size=(1, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
              for _ in prompts]
    return cfg, params, prompts, embeds


def _encdec_baseline(cfg, params, prompts, embeds, max_new):
    srv = Server(cfg, params, ServeConfig(max_len=60))
    return [
        srv.generate(
            {"tokens": jnp.asarray(p)[None], "audio_embeds": jnp.asarray(e)},
            max_new,
        )[0]
        for p, e in zip(prompts, embeds)
    ]


@pytest.mark.parametrize("chunked", [True, False])
def test_encdec_engine_matches_single_request(chunked):
    """Whisper through the paged engine: per-request encoder contexts in
    immutable cross rows, decoder self-attention paged — bit-identical to
    the single-request baseline, including a slot re-fill."""
    cfg, params, prompts, embeds = _encdec_setup()
    max_new = 8
    base = _encdec_baseline(cfg, params, prompts, embeds, max_new)
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=2, max_len=32, page_size=8, chunked_prefill=chunked,
    ))
    for i, (p, e) in enumerate(zip(prompts, embeds)):
        eng.submit(p, max_new, rid=i, arrival_step=i,
                   extras={"audio_embeds": e})
    reqs = eng.run()
    assert len(reqs) == 3 and all(r.state == "finished" for r in reqs)
    for r, b in zip(reqs, base):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), b)
    assert _idle_pages(eng.kv) == eng.kv.allocator.num_pages - 1


def test_encdec_mid_prefill_preemption_and_resume():
    """An enc-dec request preempted mid-chunked-prefill re-runs its encoder
    on re-admission (recompute discipline: the cross rows belong to the
    slot, not the request) and still matches the baseline bit for bit."""
    cfg, params, _, _ = _encdec_setup()
    rng = np.random.default_rng(9)
    short = rng.integers(0, cfg.vocab_size, size=(8,)).astype(np.int32)
    long = rng.integers(0, cfg.vocab_size, size=(16,)).astype(np.int32)
    embeds = [rng.normal(size=(1, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
              for _ in range(2)]
    max_new = 8
    base = _encdec_baseline(cfg, params, [short, long], embeds, max_new)
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=2, max_len=24, page_size=4, num_pages=9,
        prefill_tokens_per_step=4,
    ))
    a = eng.submit(short, max_new, rid=0, extras={"audio_embeds": embeds[0]})
    b = eng.submit(long, max_new, rid=1, extras={"audio_embeds": embeds[1]})
    was_preempted_mid_prefill = False
    for _ in range(200):
        if not eng.sched.has_work():
            break
        mid = b.prefilling and 0 < b.prefill_pos
        eng.step()
        if mid and b.state == "waiting":
            was_preempted_mid_prefill = True
    eng._flush_pending()
    assert was_preempted_mid_prefill, "no preemption landed mid-prefill"
    assert b.stats.n_preemptions >= 1
    np.testing.assert_array_equal(np.asarray(a.out_tokens), base[0])
    np.testing.assert_array_equal(np.asarray(b.out_tokens), base[1])
    assert _idle_pages(eng.kv) == 8


# --------------------------------------------------------------------------
# Token-level admission budget
# --------------------------------------------------------------------------

def test_prefill_token_budget_paces_admission():
    """prefill_tokens_per_step bounds the prompt tokens admitted per engine
    step (page-granular); the deprecated chunk-count knob aliases to
    chunks x chunk size."""
    cfg = _paged_cfg(block=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(6)
    long = rng.integers(0, cfg.vocab_size, size=(32,)).astype(np.int32)

    def admit_span(**knobs):
        eng = Engine(cfg, params, EngineConfig(
            max_seqs=1, max_len=48, page_size=8, **knobs,
        ))
        req = eng.submit(long, 2, rid=0)
        eng.run()
        return eng, req.stats.first_token_step - req.stats.admitted_step

    # 32-token prompt = 4 page-sized chunks
    eng, span = admit_span(prefill_tokens_per_step=8)
    assert eng.tokens_per_step == 8 and span == 3  # one chunk per step
    eng, span = admit_span(prefill_tokens_per_step=16)
    assert eng.tokens_per_step == 16 and span == 1  # two chunks per step
    # deprecated alias: chunk count x chunk size
    eng, span = admit_span(prefill_chunks_per_step=1)
    assert eng.tokens_per_step == eng.chunk_size == 8 and span == 3
    eng, span = admit_span()  # defaults: 4 chunks x 8 tokens
    assert eng.tokens_per_step == 32 and span == 0


# --------------------------------------------------------------------------
# Shared-prefix paged KV: refcounted pages, radix prefix index, COW
# --------------------------------------------------------------------------

def test_prefix_index_radix_unit():
    """PrefixIndex unit: page-aligned lookup, full-tail partial match,
    insert dedup, leaf-first LRU eviction, reclaimable accounting."""
    from repro.serve import PrefixIndex

    a = PageAllocator(10)
    idx = PrefixIndex(2, a)  # 2-token pages
    pages = a.alloc(3)
    toks = np.array([1, 2, 3, 4, 5], np.int32)
    idx.insert(toks, pages, 4)  # two full pages; token 5 is a partial tail
    assert idx.num_pages == 2
    assert a.refcount(pages[0]) == 2 and a.refcount(pages[1]) == 2
    assert a.refcount(pages[2]) == 1  # partial page never enters the index
    # full-page walk
    assert idx.lookup(np.array([1, 2, 3, 4])) == ([pages[0], pages[1]], 4)
    # the tail [3] matches the first token of the cached (3, 4) page: the
    # partially-consumed page is aliased too and the match covers the
    # whole prompt (the COW-on-divergence setup)
    assert idx.lookup(np.array([1, 2, 3])) == ([pages[0], pages[1]], 3)
    # a mid-prompt mismatch stops the walk at the page boundary
    assert idx.lookup(np.array([1, 2, 9, 4])) == ([pages[0]], 2)
    assert idx.lookup(np.array([9, 9])) == ([], 0)
    # re-insert dedups: the first publisher's pages win
    dup = a.alloc(2)
    idx.insert(toks, dup, 4)
    assert idx.num_pages == 2 and a.refcount(dup[0]) == 1
    a.unref(dup)

    # second branch, inserted after the (3, 4) leaf's last touch; then
    # touch the (1, 2) root so recency orders (3,4) < (7,8) < (1,2)
    br = a.alloc(1)
    idx.insert(np.array([7, 8]), br, 2)
    idx.lookup(np.array([1, 2]))
    a.unref(pages)  # drop the slot's references; the index keeps its own
    a.unref(br)
    assert a.refcount(pages[0]) == 1
    # an excluded page (about to be aliased by an admission) is not
    # reclaimable, and shields its ancestors too
    assert idx.reclaimable_count(exclude=[pages[1]]) == 1  # only (7, 8)
    assert idx.reclaimable_count() == 3
    # LRU, leaf-first: coldest leaf (3, 4) goes first, then (7, 8); the
    # (1, 2) root page goes only once its child is gone
    assert idx.evict_lru() == pages[1]
    assert idx.evict_lru() == br[0]
    assert idx.evict_lru() == pages[0]
    assert idx.evict_lru() is None and idx.num_pages == 0
    assert a.num_free == 9


def test_audit_balances_through_admission_lifecycle():
    """The shared auditor tracks every accounting phase: cold admission
    (slot-held), publication (index-pinned while slot-held), release
    (index-pinned only), aliased re-admission, and full drain."""
    cfg = _paged_cfg(block=4)
    kv = PagedKVCache(cfg, PagedCacheConfig(max_seqs=2, max_len=16, num_pages=6))
    assert dataclasses.astuple(kv.audit()) == (5, 5, 0, 0)  # (total, free, index_pinned, slot_held)
    A = np.arange(8, dtype=np.int32)
    kv.admit(0, A)
    assert dataclasses.astuple(kv.audit()) == (5, 2, 0, 3)
    kv.commit_prefix(0, A, 8)  # 2 full pages published; pins count as index
    assert dataclasses.astuple(kv.audit()) == (5, 2, 2, 1)
    kv.release(0)
    assert dataclasses.astuple(kv.audit()) == (5, 3, 2, 0)
    kv.admit(1, A)  # aliases both cached pages + 1 fresh tail page
    assert dataclasses.astuple(kv.audit()) == (5, 2, 2, 1)
    kv.release(1)
    assert dataclasses.astuple(kv.audit()) == (5, 3, 2, 0)


def test_audit_detects_refcount_corruption():
    """The auditor must catch each way the accounting can break: a stray
    extra reference, a leaked (vanished) reference, and a free-list /
    refcount disagreement."""
    cfg = _paged_cfg(block=4)

    def fresh():
        kv = PagedKVCache(
            cfg, PagedCacheConfig(max_seqs=2, max_len=16, num_pages=6)
        )
        kv.admit(0, np.arange(8, dtype=np.int32))
        kv.commit_prefix(0, np.arange(8, dtype=np.int32), 8)
        return kv

    kv = fresh()
    kv.audit()  # sane before corruption
    kv.allocator._ref[kv._pages[0][0]] += 1  # stray reference
    with pytest.raises(AssertionError, match="refcount"):
        kv.audit()

    kv = fresh()
    kv.allocator._ref[kv._pages[0][0]] -= 1  # leaked reference
    with pytest.raises(AssertionError, match="refcount"):
        kv.audit()

    kv = fresh()
    free_page = kv.allocator._free[-1]
    kv.allocator._ref[free_page] = 1  # referenced page left on the free list
    with pytest.raises(AssertionError, match="free-list|refcount"):
        kv.audit()


def test_kvcache_admission_aliases_cached_prefix():
    """kv-level: admit -> commit -> release leaves the prefix cached; the
    next admission aliases it (refcount 2) and LRU eviction reclaims only
    when the free list is exhausted."""
    cfg = _paged_cfg(block=4)
    kv = PagedKVCache(cfg, PagedCacheConfig(max_seqs=2, max_len=16, num_pages=6))
    assert kv.sharing and kv.skip_prefill
    A = np.arange(8, dtype=np.int32)
    assert kv.admit(0, A) == 0  # cold
    pages_a = list(kv._pages[0])
    kv.commit_prefix(0, A, 8)
    assert kv.prefix_cache_pages == 2
    kv.release(0)
    assert kv.num_free_pages == 3 and kv.prefix_cache_pages == 2
    # same prompt: both full pages alias (refcount 2 = slot + index)
    assert kv.admit(1, A) == 8
    assert kv._pages[1][:2] == pages_a[:2]
    assert kv.allocator.refcount(pages_a[0]) == 2
    kv.release(1)
    # a distinct prompt needing more than the free list evicts LRU
    B = np.arange(100, 112, dtype=np.int32)
    assert kv.can_admit(B)  # 4 pages: 3 free + evictable prefix
    assert kv.admit(0, B) == 0
    assert kv.prefix_cache_pages == 1  # deepest page evicted, root kept
    kv.release(0)
    # the surviving page still serves lookups up to its boundary
    assert kv.admit(1, A) == 4
    kv.release(1)


@pytest.mark.parametrize("mla", [False, True])
def test_shared_prefix_outputs_bit_identical(mla):
    """The tentpole guarantee: prefix sharing is a *data-placement* change,
    not a numerics change.  Requests aliasing a cached prefix — including a
    partially-consumed tail page whose first decode write diverges through
    COW — produce greedy outputs bit-identical to both the non-shared
    engine and single-request generate(), for dense/GQA and MLA latent
    pages."""
    cfg = _mla_dense_cfg() if mla else _paged_cfg(block=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(21)
    shared = rng.integers(0, cfg.vocab_size, size=(24,)).astype(np.int32)
    pa = np.concatenate([shared, rng.integers(0, cfg.vocab_size, size=(3,))
                         ]).astype(np.int32)
    pb = np.concatenate([shared, rng.integers(0, cfg.vocab_size, size=(5,))
                         ]).astype(np.int32)
    pc = shared[:20].copy()  # prefix incl. a partial page -> COW divergence
    prompts = [pa, pb, pc]
    max_new = 8
    base = _single_request_baseline(cfg, params, prompts, max_new)

    def run_engine(sharing):
        eng = Engine(cfg, params, EngineConfig(
            max_seqs=2, max_len=48, page_size=8, prefix_sharing=sharing,
        ))
        for i, p in enumerate(prompts):
            eng.submit(p, max_new, rid=i, arrival_step=4 * i)
        return eng, eng.run()

    eng_s, reqs_s = run_engine(True)
    eng_u, reqs_u = run_engine(False)
    for rs, ru, b in zip(reqs_s, reqs_u, base):
        np.testing.assert_array_equal(np.asarray(rs.out_tokens), b)
        np.testing.assert_array_equal(np.asarray(ru.out_tokens), b)
    # rid 1 aliased all 3 full prefix pages; rid 2 also aliased the partial
    # tail page (its whole prompt was cached) and diverged through COW
    assert [r.stats.cached_prompt_tokens for r in reqs_s] == [0, 24, 20]
    assert eng_s.kv.cow_copies >= 1
    assert eng_s.prefill_chunks < eng_u.prefill_chunks
    assert eng_s.kv.allocator.pages_allocated < eng_u.kv.allocator.pages_allocated
    assert not eng_u.kv.sharing and eng_u.kv.cow_copies == 0
    # refcounts exact after drain: free + index-pinned covers the pool
    assert _idle_pages(eng_s.kv) == eng_s.kv.allocator.num_pages - 1


def test_shared_prefix_zero_recompute_suffix_chunks():
    """The compute-saving contract, pinned in chunk units: an admission
    whose prefix is fully cached runs EXACTLY the suffix's chunks — one
    chunk for a one-page suffix, and a single 1-token logits chunk (write
    null-routed) when the entire prompt is cached."""
    cfg = _paged_cfg(block=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(13)
    A = rng.integers(0, cfg.vocab_size, size=(32,)).astype(np.int32)
    eng = Engine(cfg, params, EngineConfig(max_seqs=1, max_len=48, page_size=8))
    srv = Server(cfg, params, ServeConfig(max_len=64))

    eng.submit(A, 4, rid=0)
    eng.run()
    assert eng.prefill_chunks == 4  # cold: ceil(32 / 8)

    # suffix-only: 32 cached tokens + 8 new -> ONE chunk
    before = eng.prefill_chunks
    B = np.concatenate([A, rng.integers(0, cfg.vocab_size, size=(8,))
                        ]).astype(np.int32)
    rb = eng.submit(B, 4, rid=1)
    eng.run()
    assert eng.prefill_chunks - before == 1
    assert rb.stats.cached_prompt_tokens == 32
    np.testing.assert_array_equal(
        np.asarray(rb.out_tokens),
        srv.generate({"tokens": jnp.asarray(B)[None]}, 4)[0],
    )

    # fully cached: one 1-token chunk recomputes only the last position's
    # logits (its K/V write is null-routed — the cache already has it)
    before = eng.prefill_chunks
    rc = eng.submit(A.copy(), 4, rid=2)
    eng.run()
    assert eng.prefill_chunks - before == 1
    assert rc.stats.cached_prompt_tokens == 32
    np.testing.assert_array_equal(
        np.asarray(rc.out_tokens),
        srv.generate({"tokens": jnp.asarray(A)[None]}, 4)[0],
    )


def test_shared_prefix_mid_prefill_preemption_resumes_suffix():
    """A preempted suffix prefill resumes: pages the victim already
    published to the prefix index survive its release (one index-owned
    reference), so re-admission aliases them and chunks only what is left
    — with refcounts exact and outputs bit-identical throughout."""
    cfg = _paged_cfg(block=4)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    short = rng.integers(0, cfg.vocab_size, size=(8,)).astype(np.int32)
    long = rng.integers(0, cfg.vocab_size, size=(16,)).astype(np.int32)
    max_new = 8
    base = _single_request_baseline(cfg, params, [short, long], max_new)
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=2, max_len=24, page_size=4, num_pages=9,
        prefill_tokens_per_step=4,
    ))
    a = eng.submit(short, max_new, rid=0)
    b = eng.submit(long, max_new, rid=1)
    was_preempted_mid_prefill = False
    for _ in range(200):
        if not eng.sched.has_work():
            break
        mid = b.prefilling and 0 < b.prefill_pos
        eng.step()
        if mid and b.state == "waiting":
            was_preempted_mid_prefill = True
    eng._flush_pending()
    assert was_preempted_mid_prefill, "no preemption landed mid-prefill"
    assert b.stats.n_preemptions >= 1
    # the re-admission found the preempted prefill's published pages and
    # resumed at the first uncached page boundary instead of recomputing
    assert b.stats.cached_prompt_tokens >= 4
    assert b.stats.cached_prompt_tokens % 4 == 0
    np.testing.assert_array_equal(np.asarray(a.out_tokens), base[0])
    np.testing.assert_array_equal(np.asarray(b.out_tokens), base[1])
    assert _idle_pages(eng.kv) == 8


def test_prefix_sharing_capability_matrix():
    """Shareability is a per-family CacheAdapter capability — the registry
    refuses nothing: stateful families just fall through to the unshared
    path, and MoE stacks alias pages without skipping compute."""
    from repro.models import adapters as A

    expect = {
        "minicpm-2b": (True, True),  # dense/GQA: full sharing
        "qwen1.5-110b": (True, True),
        "starcoder2-7b": (True, True),
        "granite-moe-3b-a800m": (True, False),  # MoE: alias, recompute
        "deepseek-v3-671b": (True, False),  # MLA pages + MoE FFN
        "mamba2-130m": (False, False),  # SSM state rows are slot-local
        "h2o-danube-3-4b": (False, False),  # SWA rings are slot-local
        "hymba-1.5b": (False, False),  # hybrid ring+state
        "whisper-tiny": (False, False),  # audio side inputs taint the stack
    }
    for arch, (share, skip) in expect.items():
        cfg = C.get_config(arch, smoke=True, dtype=jnp.float32)
        assert A.prefix_shareable(cfg) == share, arch
        assert A.prefix_compute_skippable(cfg) == skip, arch
    # MLA over a dense FFN stack (the latent-page parity config) skips too
    assert A.prefix_compute_skippable(_mla_dense_cfg())
    # non-shareable families run with sharing requested but disabled —
    # today's path, no refusal
    ssm = C.get_config("mamba2-130m", smoke=True, dtype=jnp.float32)
    kv = PagedKVCache(ssm, PagedCacheConfig(max_seqs=1, max_len=16))
    assert not kv.sharing and kv.index is None


def test_moe_stack_shares_pages_but_recomputes():
    """'Mixed stacks share the paged segments and recompute the rest': a
    MoE config aliases prefix pages (memory dedup) while running every
    prefill chunk, so its outputs stay bit-identical to the non-shared
    chunked engine — sharing must not widen the documented multi-chunk
    MoE caveat."""
    cfg = dataclasses.replace(
        C.get_config("granite-moe-3b-a800m", smoke=True, dtype=jnp.float32),
        block=8,
    )
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    shared = rng.integers(0, cfg.vocab_size, size=(24,)).astype(np.int32)
    prompts = [
        np.concatenate([shared, rng.integers(0, cfg.vocab_size, size=(3,))
                        ]).astype(np.int32),
        np.concatenate([shared, rng.integers(0, cfg.vocab_size, size=(5,))
                        ]).astype(np.int32),
        # the prefix of an already-cached longer page run, ending mid-page:
        # a recompute family must NOT alias the partial tail page (its
        # content was dispatched under the publisher's longer chunk — the
        # regroup caveat), so the match clamps to the full-page walk
        shared[:20].copy(),
    ]

    def run_engine(sharing):
        eng = Engine(cfg, params, EngineConfig(
            max_seqs=1, max_len=40, page_size=8, prefix_sharing=sharing,
        ))
        for i, p in enumerate(prompts):
            eng.submit(p, 6, rid=i)
        return eng, eng.run()

    eng_s, reqs_s = run_engine(True)
    eng_u, reqs_u = run_engine(False)
    assert eng_s.kv.sharing and not eng_s.kv.skip_prefill
    for rs, ru in zip(reqs_s, reqs_u):  # bit-identical to non-shared
        assert rs.out_tokens == ru.out_tokens, rs.rid
    assert [r.stats.cached_prompt_tokens for r in reqs_s] == [0, 24, 16]
    assert eng_s.kv.cow_copies == 0  # no partial-tail alias -> no COW
    assert eng_s.prefill_chunks == eng_u.prefill_chunks  # no compute skip
    assert eng_s.kv.allocator.pages_allocated < eng_u.kv.allocator.pages_allocated
    # one-shot prefill groups the whole prompt per request, which a
    # recompute family cannot replay bit-exactly: sharing gates itself off
    eng_o = Engine(cfg, params, EngineConfig(
        max_seqs=1, max_len=40, page_size=8, chunked_prefill=False,
    ))
    assert not eng_o.kv.sharing
    # ...but compute-skippable families keep sharing under one-shot prefill
    assert Engine(
        _paged_cfg(block=8), M.init_params(_paged_cfg(block=8),
                                           jax.random.PRNGKey(0)),
        EngineConfig(max_seqs=1, max_len=40, page_size=8,
                     chunked_prefill=False),
    ).kv.sharing


def test_moe_capacity_dispatch_regroups_across_chunks():
    """Pin the *mechanism* of the documented multi-chunk MoE prefill
    caveat, so the known limit cannot silently widen (or silently start
    applying to single-chunk prompts): capacity dispatch ranks tokens
    within their expert per forward call, so a 16-token sequence whose
    tokens all pick one hot expert drops the overflow half when run
    one-shot but keeps it when run as two 8-token chunks.  Tokens inside
    the capacity window are bit-identical either way — which is exactly
    why single-chunk prompts and ``chunked_prefill=False`` stay exact."""
    from repro.models import ffn as ffnm

    cfg = dataclasses.replace(
        C.get_config("granite-moe-3b-a800m", smoke=True, dtype=jnp.float32),
        capacity_factor=1.0,
    )
    p = ffnm.moe_init(jax.random.PRNGKey(0), cfg)
    # near-identical tokens: every token routes to the same hot expert, so
    # 16 one-shot tokens overflow Cg = 8 while each 8-token chunk fits
    base = jax.random.normal(jax.random.PRNGKey(1), (cfg.d_model,), jnp.float32)
    noise = jax.random.normal(jax.random.PRNGKey(2), (1, 16, cfg.d_model),
                              jnp.float32)
    x = jnp.broadcast_to(base, (1, 16, cfg.d_model)) + 1e-2 * noise
    logits = np.asarray(x[0].astype(jnp.float32) @ p["router"])
    top1 = logits.argmax(-1)
    assert (top1 == top1[0]).all(), "setup: tokens must share a hot expert"
    full, _ = ffnm.moe_forward(p, cfg, x)
    c1, _ = ffnm.moe_forward(p, cfg, x[:, :8])
    c2, _ = ffnm.moe_forward(p, cfg, x[:, 8:])
    chunked = jnp.concatenate([c1, c2], axis=1)
    # within capacity: identical dispatch, identical bits
    np.testing.assert_array_equal(np.asarray(full[:, :8]),
                                  np.asarray(chunked[:, :8]))
    # past capacity: the one-shot run dropped these tokens' hot-expert
    # contribution, the per-chunk runs kept it — outputs must differ
    assert not np.array_equal(np.asarray(full[:, 8:]),
                              np.asarray(chunked[:, 8:]))


def test_moe_unchunked_multi_page_engine_parity():
    """The caveat's boundary from the other side: with chunking off, a
    multi-page MoE prompt through the paged engine sees the one-shot
    dispatch grouping and stays bit-identical to the baseline."""
    cfg = dataclasses.replace(
        C.get_config("granite-moe-3b-a800m", smoke=True, dtype=jnp.float32),
        block=8,
    )
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
               for n in (17, 20)]
    base = _single_request_baseline(cfg, params, prompts, 6)
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=2, max_len=32, page_size=8, chunked_prefill=False,
    ))
    for i, pr in enumerate(prompts):
        eng.submit(pr, 6, rid=i)
    for r, b in zip(eng.run(), base):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), b)


def test_prefill_chunks_per_step_deprecation_warning(monkeypatch):
    """The chunk-count admission alias warns exactly once per process and
    only when explicitly set; the default (None) derives the same budget
    silently."""
    import warnings as _warnings

    from repro.serve import engine as E

    cfg = _paged_cfg(block=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    monkeypatch.setattr(E, "_chunks_alias_warned", False)
    with _warnings.catch_warnings(record=True) as rec:
        _warnings.simplefilter("always")
        eng = Engine(cfg, params, EngineConfig(
            max_seqs=1, max_len=16, page_size=8,
        ))
        assert eng.tokens_per_step == 4 * eng.chunk_size  # alias default
    assert not rec  # default config: no warning
    with pytest.warns(DeprecationWarning, match="prefill_chunks_per_step"):
        eng = Engine(cfg, params, EngineConfig(
            max_seqs=1, max_len=16, page_size=8, prefill_chunks_per_step=2,
        ))
    assert eng.tokens_per_step == 2 * eng.chunk_size
    with _warnings.catch_warnings(record=True) as rec:
        _warnings.simplefilter("always")
        Engine(cfg, params, EngineConfig(
            max_seqs=1, max_len=16, page_size=8, prefill_chunks_per_step=2,
        ))
    assert not rec  # one-shot: second use stays silent


# --------------------------------------------------------------------------
# Pallas paged-decode backend (fused kernels; interpret mode off-TPU)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("chunked", [True, False])
def test_pallas_backend_matches_single_request(chunked):
    """Dense/GQA engine under backend="pallas": the fused paged-attention
    decode + COW kernels are a *data-movement* change, not a numerics
    change — greedy tokens equal the single-request generate() baseline
    exactly, including multi-chunk prompts and a slot re-fill."""
    cfg = _paged_cfg(block=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
               for n in (12, 9, 14)]
    max_new = 8
    base = _single_request_baseline(cfg, params, prompts, max_new)
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=2, max_len=32, page_size=8, chunked_prefill=chunked,
        backend="pallas",
    ))
    assert eng.cfg.decode_backend == "pallas"  # folded into the jit key
    for i, p in enumerate(prompts):
        eng.submit(p, max_new, rid=i, arrival_step=2 * i)
    reqs = eng.run()
    assert len(reqs) == 3 and all(r.state == "finished" for r in reqs)
    for r, b in zip(reqs, base):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), b)
    assert _idle_pages(eng.kv) == eng.kv.allocator.num_pages - 1


@pytest.mark.parametrize("chunked", [True, False])
def test_pallas_backend_mla_matches_single_request(chunked):
    """MLA under backend="pallas": absorbed-matmul decode over streamed
    latent pages matches the single-request baseline token-for-token."""
    cfg = _mla_dense_cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
               for n in (12, 9, 14)]
    max_new = 8
    base = _single_request_baseline(cfg, params, prompts, max_new)
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=2, max_len=32, page_size=8, chunked_prefill=chunked,
        backend="pallas",
    ))
    for i, p in enumerate(prompts):
        eng.submit(p, max_new, rid=i, arrival_step=2 * i)
    reqs = eng.run()
    assert len(reqs) == 3 and all(r.state == "finished" for r in reqs)
    for r, b in zip(reqs, base):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), b)
    assert _idle_pages(eng.kv) == eng.kv.allocator.num_pages - 1


@pytest.mark.parametrize("mla", [False, True])
def test_pallas_backend_cow_divergence(mla):
    """Shared-prefix serving under backend="pallas": the COW copy runs
    through the scalar-prefetched page-copy kernel and the post-divergence
    decode reads through the fused attention kernel — outputs stay equal to
    the baseline, and at least one COW actually fired."""
    cfg = _mla_dense_cfg() if mla else _paged_cfg(block=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(21)
    shared = rng.integers(0, cfg.vocab_size, size=(24,)).astype(np.int32)
    pa = np.concatenate([shared, rng.integers(0, cfg.vocab_size, size=(3,))
                         ]).astype(np.int32)
    pc = shared[:20].copy()  # partial tail page -> COW on first decode write
    prompts = [pa, pc]
    max_new = 8
    base = _single_request_baseline(cfg, params, prompts, max_new)
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=2, max_len=48, page_size=8, prefix_sharing=True,
        backend="pallas",
    ))
    for i, p in enumerate(prompts):
        eng.submit(p, max_new, rid=i, arrival_step=4 * i)
    reqs = eng.run()
    for r, b in zip(reqs, base):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), b)
    assert eng.kv.cow_copies >= 1
    assert [r.stats.cached_prompt_tokens for r in reqs] == [0, 20]
    assert _idle_pages(eng.kv) == eng.kv.allocator.num_pages - 1


def test_pallas_backend_ring_swa_fallback_unchanged():
    """Families without paged decode (SWA ring buffer) ignore the backend
    selector: backend="pallas" still runs the ring path and stays
    bit-identical to the baseline."""
    cfg = C.get_config("h2o-danube-3-4b", smoke=True, dtype=jnp.float32)
    cfg = dataclasses.replace(cfg, block=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
               for n in (12, 9)]
    max_new = 8
    base = _single_request_baseline(cfg, params, prompts, max_new)
    eng = Engine(cfg, params, EngineConfig(
        max_seqs=2, max_len=32, page_size=8, backend="pallas",
    ))
    for i, p in enumerate(prompts):
        eng.submit(p, max_new, rid=i)
    for r, b in zip(eng.run(), base):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), b)


def test_engine_rejects_unknown_backend():
    """An unknown backend name fails at Engine construction (eager
    resolve), not mid-trace inside a jitted step."""
    cfg = _paged_cfg(block=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="unknown backend"):
        Engine(cfg, params, EngineConfig(
            max_seqs=1, max_len=16, page_size=8, backend="cuda",
        ))


def test_make_requests_deterministic():
    a = make_requests(100, 5, mean_interarrival=3.0, seed=7)
    b = make_requests(100, 5, mean_interarrival=3.0, seed=7)
    for ra, rb in zip(a, b):
        assert ra["arrival_step"] == rb["arrival_step"]
        assert ra["max_new_tokens"] == rb["max_new_tokens"]
        np.testing.assert_array_equal(ra["prompt"], rb["prompt"])
    assert a[-1]["arrival_step"] > 0  # arrivals actually stagger

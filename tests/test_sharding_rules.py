"""Sharding-rule validity for every (arch × mesh) without real devices.

Uses AbstractMesh so the 512-way production meshes can be validated in the
same process as the 1-device tests (jax locks the device count at init).
"""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as C
from repro.configs.shapes import cache_specs
from repro.distributed import sharding as SH
from repro.distributed.axes import abstract_mesh
from repro.models import model as M

MESHES = {
    "single": abstract_mesh((16, 16), ("data", "model")),
    "multi": abstract_mesh((2, 16, 16), ("pod", "data", "model")),
}


def _axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def _check_spec_tree(mesh, shapes_tree, specs_tree, *, allow_uneven=False):
    sizes = _axis_sizes(mesh)
    leaves_shape = jax.tree.leaves(shapes_tree)
    leaves_spec = jax.tree.leaves(
        specs_tree, is_leaf=lambda x: isinstance(x, P)
    )
    assert len(leaves_shape) == len(leaves_spec)
    for leaf, spec in zip(leaves_shape, leaves_spec):
        assert isinstance(spec, P)
        assert len(spec) <= len(leaf.shape), (leaf.shape, spec)
        used = []
        for dim, entry in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            n = int(np.prod([sizes[a] for a in axes]))
            used.extend(axes)
            if not allow_uneven:
                assert dim % n == 0, (leaf.shape, spec)
        assert len(used) == len(set(used)), f"axis reused: {spec}"


@pytest.mark.parametrize("arch", C.arch_ids())
@pytest.mark.parametrize("mesh_name", ["single", "multi"])
def test_param_specs_valid(arch, mesh_name):
    cfg = C.get_config(arch)
    mesh = MESHES[mesh_name]
    params_shape = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0))
    )
    for mode in ("train", "serve"):
        specs = SH.param_pspecs(cfg, mesh, params_shape, mode=mode)
        _check_spec_tree(mesh, params_shape, specs)


@pytest.mark.parametrize("arch", ["qwen1.5-110b", "mamba2-130m",
                                  "h2o-danube-3-4b", "deepseek-v3-671b"])
def test_cache_specs_valid(arch):
    cfg = C.get_config(arch)
    mesh = MESHES["single"]
    cs = cache_specs(cfg, 128, 32768)
    specs = SH.cache_pspecs(cfg, mesh, cs)
    _check_spec_tree(mesh, cs, specs)


def test_small_model_is_replicated_in_train():
    cfg = C.get_config("mamba2-130m")
    mesh = MESHES["single"]
    params_shape = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0))
    )
    specs = SH.param_pspecs(cfg, mesh, params_shape, mode="train")
    for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        assert all(e is None for e in s), s


def test_serve_mode_uses_all_axes_for_110b():
    cfg = C.get_config("qwen1.5-110b")
    mesh = MESHES["single"]
    params_shape = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0))
    )
    specs = SH.param_pspecs(cfg, mesh, params_shape, mode="serve")
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P)
    )[0]
    # FFN weights must be sharded over (data, model) = 256-way resident
    found = False
    for path, spec in flat:
        if "w_gate" in SH._path_str(path):
            assert any(isinstance(e, tuple) and set(e) == {"data", "model"}
                       for e in spec if e is not None), spec
            found = True
    assert found


def test_zero_extension_shards_moments_512_ways():
    cfg = C.get_config("deepseek-v3-671b")
    mesh = MESHES["multi"]
    params_shape = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0))
    )
    specs = SH.param_pspecs(cfg, mesh, params_shape, mode="train")
    sizes = _axis_sizes(mesh)
    # the expert weights (dominant storage) must be sharded >= 256 ways
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P)
    )[0]
    for path, spec in flat:
        if "moe/w_gate" in SH._path_str(path).replace("seg1/", "moe_") or \
           ("w_gate" in SH._path_str(path) and "moe" in SH._path_str(path)):
            ways = 1
            for e in spec:
                if e is None:
                    continue
                for a in (e if isinstance(e, tuple) else (e,)):
                    ways *= sizes[a]
            assert ways >= 256, (spec, ways)

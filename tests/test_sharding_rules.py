"""Sharding-rule validity for every (arch × mesh) without real devices.

Uses AbstractMesh so the 512-way production meshes can be validated in the
same process as the 1-device tests (jax locks the device count at init).
"""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as C
from repro.configs.shapes import cache_specs
from repro.distributed import sharding as SH
from repro.distributed.axes import abstract_mesh
from repro.models import model as M

MESHES = {
    "single": abstract_mesh((16, 16), ("data", "model")),
    "multi": abstract_mesh((2, 16, 16), ("pod", "data", "model")),
}


def _axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def _check_spec_tree(mesh, shapes_tree, specs_tree, *, allow_uneven=False):
    sizes = _axis_sizes(mesh)
    leaves_shape = jax.tree.leaves(shapes_tree)
    leaves_spec = jax.tree.leaves(
        specs_tree, is_leaf=lambda x: isinstance(x, P)
    )
    assert len(leaves_shape) == len(leaves_spec)
    for leaf, spec in zip(leaves_shape, leaves_spec):
        assert isinstance(spec, P)
        assert len(spec) <= len(leaf.shape), (leaf.shape, spec)
        used = []
        for dim, entry in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            n = int(np.prod([sizes[a] for a in axes]))
            used.extend(axes)
            if not allow_uneven:
                assert dim % n == 0, (leaf.shape, spec)
        assert len(used) == len(set(used)), f"axis reused: {spec}"


@pytest.mark.parametrize("arch", C.arch_ids())
@pytest.mark.parametrize("mesh_name", ["single", "multi"])
def test_param_specs_valid(arch, mesh_name):
    cfg = C.get_config(arch)
    mesh = MESHES[mesh_name]
    params_shape = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0))
    )
    for mode in ("train", "serve"):
        specs = SH.param_pspecs(cfg, mesh, params_shape, mode=mode)
        _check_spec_tree(mesh, params_shape, specs)


@pytest.mark.parametrize("arch", ["qwen1.5-110b", "mamba2-130m",
                                  "h2o-danube-3-4b", "deepseek-v3-671b"])
def test_cache_specs_valid(arch):
    cfg = C.get_config(arch)
    mesh = MESHES["single"]
    cs = cache_specs(cfg, 128, 32768)
    specs = SH.cache_pspecs(cfg, mesh, cs)
    _check_spec_tree(mesh, cs, specs)


def test_small_model_is_replicated_in_train():
    cfg = C.get_config("mamba2-130m")
    mesh = MESHES["single"]
    params_shape = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0))
    )
    specs = SH.param_pspecs(cfg, mesh, params_shape, mode="train")
    for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        assert all(e is None for e in s), s


def test_serve_mode_uses_all_axes_for_110b():
    cfg = C.get_config("qwen1.5-110b")
    mesh = MESHES["single"]
    params_shape = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0))
    )
    specs = SH.param_pspecs(cfg, mesh, params_shape, mode="serve")
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P)
    )[0]
    # FFN weights must be sharded over (data, model) = 256-way resident
    found = False
    for path, spec in flat:
        if "w_gate" in SH._path_str(path):
            assert any(isinstance(e, tuple) and set(e) == {"data", "model"}
                       for e in spec if e is not None), spec
            found = True
    assert found


@pytest.mark.parametrize("arch", C.arch_ids())
@pytest.mark.parametrize("tp", [2, 4, 8])
def test_paged_cache_pool_specs_valid(arch, tp):
    """Every cache family's pool specs stay valid on 2/4/8-way model axes:
    sharded axes divide the pool shapes (the adapter emits head sharding
    only when the kv-head axis divides), page tables never enter the tree,
    and the divisibility invariant holds for the L-stacked pool layout."""
    import jax.numpy as jnp

    from repro.models import adapters as A

    cfg = C.get_config(arch, smoke=True, dtype=jnp.float32)
    if A.unsupported_message(cfg) is not None:
        pytest.skip("family is Server-only (no paged pools)")
    mesh = abstract_mesh((1, tp), ("data", "model"))
    pools = jax.eval_shape(lambda: M.init_paged_cache(cfg, 2, 5, 8, 32))
    specs = SH.paged_cache_pspecs(cfg, mesh, pools)
    _check_spec_tree(mesh, pools, specs)
    # when the kv-head axis divides, paged K/V pools must actually shard
    if cfg.n_kv_heads and cfg.n_kv_heads % tp == 0 and any(
        isinstance(ad, A.PagedAttnAdapter) for ad in A.all_adapters(cfg)
    ):
        flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert any("model" in tuple(s) for s in flat), specs


@pytest.mark.parametrize("tp", [4, 8])
def test_paged_sharding_validation_rejects_nondividing(tp):
    """Construction-time rejection: paged kv-heads that cannot divide the
    model axis raise with the valid TP sizes named (no silent replication)."""
    import jax.numpy as jnp

    cfg = C.get_config("minicpm-2b", smoke=True, dtype=jnp.float32)  # hkv=6
    mesh = abstract_mesh((1, tp), ("data", "model"))
    with pytest.raises(ValueError, match="n_kv_heads=6"):
        SH.validate_paged_sharding(cfg, mesh)
    # 2-way divides; MLA (no paged head axis) passes at any size
    SH.validate_paged_sharding(cfg, abstract_mesh((1, 2), ("data", "model")))
    mla = C.get_config("deepseek-v3-671b", smoke=True, dtype=jnp.float32)
    SH.validate_paged_sharding(mla, mesh)


def test_zero_extension_shards_moments_512_ways():
    cfg = C.get_config("deepseek-v3-671b")
    mesh = MESHES["multi"]
    params_shape = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0))
    )
    specs = SH.param_pspecs(cfg, mesh, params_shape, mode="train")
    sizes = _axis_sizes(mesh)
    # the expert weights (dominant storage) must be sharded >= 256 ways
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P)
    )[0]
    for path, spec in flat:
        if "moe/w_gate" in SH._path_str(path).replace("seg1/", "moe_") or \
           ("w_gate" in SH._path_str(path) and "moe" in SH._path_str(path)):
            ways = 1
            for e in spec:
                if e is None:
                    continue
                for a in (e if isinstance(e, tuple) else (e,)):
                    ways *= sizes[a]
            assert ways >= 256, (spec, ways)

"""Fixture tests for the repo-specific lint pass (repro.analysis.staticcheck).

Per rule: a true-positive snippet, a true-negative snippet (the idiom the
repo actually uses), and pragma suppression.  Plus pragma parsing, baseline
round-tripping, and the acceptance gate that the tree itself is clean.
"""
import textwrap
from pathlib import Path

import pytest

from repro.analysis.staticcheck import (
    RULE_DOCS,
    RULE_IDS,
    Finding,
    check_paths,
    check_source,
    format_baseline,
    load_baseline,
    parse_pragmas,
    split_by_baseline,
)

REPO = Path(__file__).resolve().parent.parent


def _rules(src, path="src/repro/fake.py", select=None):
    """Rule ids found in a dedented snippet."""
    findings = check_source(textwrap.dedent(src), path=path, rules=select)
    return [f.rule for f in findings]


# --------------------------------------------------------------------------
# RPR001 — use-after-donation
# --------------------------------------------------------------------------

_DONATING_PRELUDE = """
    import jax

    def _donate_caches():
        return (1,)

    def _decode_fn(cfg):
        return jax.jit(step, donate_argnums=_donate_caches())
"""


def test_rpr001_positive_direct_jit():
    src = """
        import jax
        fn = jax.jit(step, donate_argnums=(0,))

        def drive(data):
            out = fn(data)
            return data.sum()  # read after donation
    """
    assert _rules(src, select=["RPR001"]) == ["RPR001"]


def test_rpr001_positive_factory_attr_binding():
    src = _DONATING_PRELUDE + """
    class Engine:
        def __init__(self, cfg):
            self._decode = _decode_fn(cfg)

        def run(self):
            out = self._decode(self.params, self.kv.data)
            return self.kv.data  # donated buffer read before rebinding
    """
    assert _rules(src, select=["RPR001"]) == ["RPR001"]


def test_rpr001_negative_same_statement_rebind():
    src = _DONATING_PRELUDE + """
    class Engine:
        def __init__(self, cfg):
            self._decode = _decode_fn(cfg)

        def run(self):
            out, self.kv.data = self._decode(self.params, self.kv.data)
            return self.kv.data  # rebound in the donating statement
    """
    assert _rules(src, select=["RPR001"]) == []


def test_rpr001_negative_rebind_before_read():
    src = """
        import jax
        fn = jax.jit(step, donate_argnums=(0,))

        def drive(data):
            fn(data)
            data = fresh()
            return data.sum()
    """
    assert _rules(src, select=["RPR001"]) == []


def test_rpr001_negative_fresh_temporary():
    src = """
        import jax
        fn = jax.jit(step, donate_argnums=(0,))

        def drive(x):
            return fn(jnp.asarray(x))  # donated value is a fresh temp
    """
    assert _rules(src, select=["RPR001"]) == []


def test_rpr001_noqa():
    src = """
        import jax
        fn = jax.jit(step, donate_argnums=(0,))

        def drive(data):
            out = fn(data)
            return data.sum()  # repro: noqa RPR001 -- test fixture
    """
    assert _rules(src, select=["RPR001"]) == []


# --------------------------------------------------------------------------
# RPR002 — host sync in a hot-loop function
# --------------------------------------------------------------------------

def test_rpr002_positive_all_sync_forms():
    src = """
        import numpy as np

        def step(self):  # repro: hot-loop
            a = np.asarray(self.tokens)
            b = np.stack([a, a])
            c = int(self.greedy)
            d = float(self.logits)
            e = self.tokens.item()
            return a, b, c, d, e
    """
    assert _rules(src, select=["RPR002"]) == ["RPR002"] * 5


def test_rpr002_negative_unmarked_function():
    src = """
        import numpy as np

        def intake(self):  # not marked hot: syncs here are fine
            return np.asarray(self.prompt)
    """
    assert _rules(src, select=["RPR002"]) == []


def test_rpr002_negative_device_side_ops():
    src = """
        import jax.numpy as jnp

        def step(self):  # repro: hot-loop
            x = jnp.asarray(self.table)     # host->device upload, not a sync
            n = int("42")                   # constant: no device value
            return x, n
    """
    assert _rules(src, select=["RPR002"]) == []


def test_rpr002_marker_on_preceding_line():
    src = """
        import numpy as np

        # repro: hot-loop
        def step(self):
            return np.asarray(self.tokens)
    """
    assert _rules(src, select=["RPR002"]) == ["RPR002"]


def test_rpr002_noqa():
    src = """
        import numpy as np

        def step(self):  # repro: hot-loop
            return np.asarray(self.done)  # repro: noqa RPR002 -- sanctioned
    """
    assert _rules(src, select=["RPR002"]) == []


# --------------------------------------------------------------------------
# RPR003 — jit constructed under a loop
# --------------------------------------------------------------------------

def test_rpr003_positive_loop_and_comprehension():
    src = """
        import jax

        def serve(reqs):
            for r in reqs:
                fn = jax.jit(make_step(r))   # re-traces per request
                fn(r)
            fns = [jax.jit(f) for f in fs]
            return fns
    """
    assert _rules(src, select=["RPR003"]) == ["RPR003", "RPR003"]


def test_rpr003_negative_hoisted_and_factory():
    src = """
        import jax
        import functools

        @functools.lru_cache(maxsize=None)
        def _decode_fn(cfg):
            return jax.jit(functools.partial(step, cfg))

        def serve(reqs, cfg):
            fn = _decode_fn(cfg)  # memoized: constructed once
            for r in reqs:
                fn(r)
    """
    assert _rules(src, select=["RPR003"]) == []


def test_rpr003_negative_def_inside_loop():
    src = """
        import jax

        def outer(items):
            for it in items:
                def helper():
                    return jax.jit(f)  # constructed only when called
    """
    assert _rules(src, select=["RPR003"]) == []


def test_rpr003_noqa():
    src = """
        import jax

        def sweep(cfgs):
            for cfg in cfgs:
                fn = jax.jit(step)  # repro: noqa RPR003 -- one-off bench sweep
                fn(cfg)
    """
    assert _rules(src, select=["RPR003"]) == []


# --------------------------------------------------------------------------
# RPR004 — family branch outside the registry
# --------------------------------------------------------------------------

def test_rpr004_positive_eq_and_membership():
    src = """
        def pick(cfg):
            if cfg.family == "mla":
                return 1
            if cfg.family in ("ssm", "hybrid"):
                return 2
            if "encdec" != cfg.family:
                return 3
    """
    assert _rules(src, select=["RPR004"]) == ["RPR004"] * 3


def test_rpr004_negative_in_registry_file():
    src = """
        def pick(cfg):
            if cfg.family == "mla":
                return 1
    """
    assert _rules(src, path="src/repro/models/adapters.py",
                  select=["RPR004"]) == []


def test_rpr004_negative_unrelated_string_compare():
    src = """
        def check(mode, name):
            if mode == "ssm":          # no family-ish subject in sight
                return 1
            if name == "dense_layer":  # not a family literal
                return 2
    """
    assert _rules(src, select=["RPR004"]) == []


def test_rpr004_noqa_line_and_file():
    line = """
        def pick(cfg):
            return cfg.family == "mla"  # repro: noqa RPR004 -- fixture
    """
    assert _rules(line, select=["RPR004"]) == []
    file_wide = """
        # repro: noqa-file RPR004 -- per-family math module
        def pick(cfg):
            a = cfg.family == "mla"
            b = cfg.family == "ssm"
            return a or b
    """
    assert _rules(file_wide, select=["RPR004"]) == []


# --------------------------------------------------------------------------
# RPR005 — stray debug output in src/
# --------------------------------------------------------------------------

def test_rpr005_positive_in_src():
    src = """
        import jax

        def f(x):
            print(x)
            jax.debug.print("x={}", x)
            breakpoint()
    """
    assert _rules(src, select=["RPR005"]) == ["RPR005"] * 3


def test_rpr005_negative_outside_src():
    src = """
        def f(x):
            print(x)
    """
    assert _rules(src, path="tests/test_fake.py", select=["RPR005"]) == []
    assert _rules(src, path="benchmarks/bench.py", select=["RPR005"]) == []


def test_rpr005_noqa_file():
    src = """
        # repro: noqa-file RPR005 -- CLI driver
        def report(x):
            print(x)
            print(x * 2)
    """
    assert _rules(src, select=["RPR005"]) == []


# --------------------------------------------------------------------------
# RPR006 — explicit device->host transfer in a hot-loop function
# --------------------------------------------------------------------------

def test_rpr006_positive_transfers():
    src = """
        import jax
        import numpy as np

        def step(self, x):  # repro: hot-loop
            host = jax.device_get(x)
            arr = np.array(x)
            x.block_until_ready()
            return host
    """
    assert _rules(src, select=["RPR006"]) == ["RPR006"] * 3


def test_rpr006_negative_outside_hot_loop():
    src = """
        import jax
        import numpy as np

        def report(x):
            return np.array(jax.device_get(x))
    """
    assert _rules(src, select=["RPR006"]) == []


def test_rpr006_negative_np_array_of_constant():
    src = """
        import numpy as np

        def step(self):  # repro: hot-loop
            return np.array([0, 1, 2])
    """
    assert _rules(src, select=["RPR006"]) == []


def test_rpr006_pragma_suppression():
    src = """
        import jax

        def step(self, x):  # repro: hot-loop
            return jax.device_get(x)  # repro: noqa RPR006 -- sanctioned sync
    """
    assert _rules(src, select=["RPR006"]) == []


# --------------------------------------------------------------------------
# RPR007 — hard-coded device selection in serve/
# --------------------------------------------------------------------------

_SERVE_PATH = "src/repro/serve/fake.py"


def test_rpr007_positive_device_index_and_bare_device_put():
    src = """
        import jax

        def place(params, pool):
            dev = jax.devices()[0]
            other = jax.local_devices()[1]
            params = jax.device_put(params)
            return dev, other, params
    """
    assert _rules(src, path=_SERVE_PATH, select=["RPR007"]) == ["RPR007"] * 3


def test_rpr007_negative_sharded_device_put():
    src = """
        import jax

        def place(params, param_sh, pool, pool_sh):
            params = jax.device_put(params, param_sh)
            pool = jax.device_put(pool, device=pool_sh)
            n = len(jax.devices())
            return params, pool, n
    """
    assert _rules(src, path=_SERVE_PATH, select=["RPR007"]) == []


def test_rpr007_negative_outside_serve_tree():
    src = """
        import jax
        dev = jax.devices()[0]
    """
    assert _rules(src, path="src/repro/launch/fake.py", select=["RPR007"]) == []
    assert _rules(src, path="tests/serve/fake.py", select=["RPR007"]) == []


def test_rpr007_noqa():
    src = """
        import jax

        def place(x):
            return jax.device_put(x)  # repro: noqa RPR007 -- host staging
    """
    assert _rules(src, path=_SERVE_PATH, select=["RPR007"]) == []


# --------------------------------------------------------------------------
# CLI --format json
# --------------------------------------------------------------------------

def test_cli_format_json(tmp_path, capsys):
    import json

    from repro.analysis.staticcheck.__main__ import main

    bad = tmp_path / "src" / "repro" / "fake.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("def f(x):\n    print(x)\n", encoding="utf-8")
    rc = main([str(bad), "--format", "json", "--no-baseline"])
    report = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert report["status"] == "findings"
    assert report["n_new"] == 1
    assert report["findings"][0]["rule"] == "RPR005"
    assert report["findings"][0]["line"] == 2

    good = tmp_path / "src" / "repro" / "ok.py"
    good.write_text("def f(x):\n    return x\n", encoding="utf-8")
    rc = main([str(good), "--format", "json", "--no-baseline"])
    report = json.loads(capsys.readouterr().out)
    assert rc == 0
    assert report == {
        "tool": "staticcheck", "status": "clean", "n_new": 0,
        "n_baselined": 0, "findings": [],
    }


# --------------------------------------------------------------------------
# Pragmas, baseline, CLI plumbing
# --------------------------------------------------------------------------

def test_pragma_parsing():
    src = textwrap.dedent("""
        x = 1  # repro: noqa RPR001, RPR004 -- two rules
        y = 2  # repro: noqa
        # repro: noqa-file RPR005 -- whole file
        # repro: hot-loop
        def f():
            pass
    """)
    p = parse_pragmas(src)
    assert p.line_noqa[2] == {"RPR001", "RPR004"}
    assert p.line_noqa[3] == set(RULE_IDS)  # bare noqa: all rules
    assert p.file_noqa == {"RPR005"}
    assert p.hot_lines == {5}
    assert p.suppressed("RPR005", 999)  # file-wide, any line


def test_pragma_unknown_rule_rejected():
    with pytest.raises(ValueError, match="unknown rule"):
        check_source("x = 1  # repro: noqa RPR999\n", path="src/x.py")


def test_pragma_ignores_lookalike_comments():
    src = "x = 1  # repro: this is prose, not a pragma\n"
    assert parse_pragmas(src).line_noqa == {}


def test_baseline_roundtrip(tmp_path):
    f1 = Finding(rule="RPR005", path="src/a.py", line=3, col=0,
                 message="m", snippet="  print(x)")
    f2 = Finding(rule="RPR004", path="src/b.py", line=7, col=4,
                 message="m", snippet='if cfg.family == "mla":')
    bl = tmp_path / "staticcheck.baseline"
    bl.write_text(format_baseline([f1, f2]))
    entries = load_baseline(bl)
    assert entries == {f1.baseline_key(), f2.baseline_key()}
    # line numbers may drift without invalidating the baseline
    moved = Finding(rule="RPR005", path="src/a.py", line=30, col=2,
                    message="m", snippet="    print(x)")
    new, old = split_by_baseline([moved, f2], entries)
    assert new == [] and old == [moved, f2]
    # an edited line is a NEW finding
    edited = Finding(rule="RPR005", path="src/a.py", line=3, col=0,
                     message="m", snippet="print(y)")
    new, _ = split_by_baseline([edited], entries)
    assert new == [edited]


def test_syntax_error_reported_not_raised():
    findings = check_source("def broken(:\n", path="src/x.py")
    assert len(findings) == 1 and findings[0].rule == "RPR000"


def test_rule_table_complete():
    assert set(RULE_IDS) == set(RULE_DOCS)
    from repro.analysis.staticcheck.rules import RULES
    assert set(RULES) == set(RULE_IDS)


def test_tree_is_clean():
    """Acceptance gate: the repo's own src/tests/benchmarks lint clean
    (fix or pragma findings — don't grow the baseline)."""
    findings = check_paths(
        [str(REPO / "src"), str(REPO / "tests"), str(REPO / "benchmarks")]
    )
    baseline_file = REPO / "staticcheck.baseline"
    baseline = load_baseline(baseline_file) if baseline_file.exists() else set()
    # keys are repo-relative in the checked-in baseline; findings here carry
    # absolute paths, so compare on the relative form
    rel = [
        Finding(f.rule, str(Path(f.path).relative_to(REPO)), f.line, f.col,
                f.message, f.snippet)
        for f in findings
    ]
    new, _ = split_by_baseline(rel, baseline)
    assert not new, "\n".join(f.format() for f in new)

"""System behaviour: training loop, checkpoint/restart, elastic resharding,
straggler hooks, serving engine, data determinism, grad compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLMData
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.optim import OptConfig, adamw_init, wsd_schedule
from repro.serve import ServeConfig, Server
from repro.train import Trainer, TrainerConfig


def _tiny_cfg():
    return C.get_config("minicpm-2b", smoke=True, dtype=jnp.float32,
                        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                        d_head=16, d_ff=128, vocab_size=256)


def test_training_reduces_loss(tmp_path):
    cfg = _tiny_cfg()
    mesh = make_local_mesh()
    tc = TrainerConfig(steps=30, checkpoint_every=0, log_every=10,
                       checkpoint_dir=None)
    tr = Trainer(cfg, mesh, tc, OptConfig(lr=3e-3))
    data = SyntheticLMData(cfg, global_batch=8, seq_len=32)
    _, _, hist = tr.fit(data)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.3


def test_checkpoint_restart_bitexact(tmp_path):
    """Fault-tolerance: kill after N steps, restart, final state must equal
    the uninterrupted run (deterministic data + restored state)."""
    cfg = _tiny_cfg()
    mesh = make_local_mesh()
    data = SyntheticLMData(cfg, global_batch=8, seq_len=32)

    # uninterrupted run: 10 steps
    tc_a = TrainerConfig(steps=10, checkpoint_every=0, log_every=100)
    tr_a = Trainer(cfg, mesh, tc_a, OptConfig(lr=1e-3))
    params_a, _, _ = tr_a.fit(data)

    # interrupted run: 5 steps + checkpoint, then "crash" and restart
    d = str(tmp_path / "ckpt")
    tc_b = TrainerConfig(steps=5, checkpoint_every=0, log_every=100,
                         checkpoint_dir=d)
    tr_b = Trainer(cfg, mesh, tc_b, OptConfig(lr=1e-3))
    tr_b.fit(data)  # saves final at step 5
    tc_c = TrainerConfig(steps=10, checkpoint_every=0, log_every=100,
                         checkpoint_dir=d)
    tr_c = Trainer(cfg, mesh, tc_c, OptConfig(lr=1e-3))
    step0, params, opt = tr_c.restore_or_init()
    assert step0 == 5
    params_c, _, _ = tr_c.fit(data)

    for a, c in zip(jax.tree.leaves(params_a), jax.tree.leaves(params_c)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32),
                                   rtol=2e-4, atol=2e-4)


def test_checkpoint_atomicity_and_gc(tmp_path):
    m = CheckpointManager(str(tmp_path), keep_last_k=2)
    tree = {"a": jnp.ones((4, 4)), "b": {"c": jnp.zeros((2,))}}
    for s in (1, 2, 3, 4):
        m.save(s, tree, blocking=True)
    assert m.available_steps() == [3, 4]  # gc keeps last 2
    step, restored = m.restore(tree)
    assert step == 4
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.ones((4, 4)))


def test_elastic_restore_onto_different_sharding(tmp_path):
    """Checkpoint written under one mesh restores onto another (node loss)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    cfg = _tiny_cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    m = CheckpointManager(str(tmp_path))
    m.save(7, params, blocking=True)
    mesh = make_local_mesh()  # "new cluster"
    shardings = jax.tree.map(
        lambda _: NamedSharding(mesh, P()), params
    )
    step, restored = m.restore(params, shardings=shardings)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_watchdog_records():
    cfg = _tiny_cfg()
    mesh = make_local_mesh()
    tc = TrainerConfig(steps=3, checkpoint_every=0, log_every=100,
                       step_deadline_s=1e-9)  # everything is a straggler
    tr = Trainer(cfg, mesh, tc)
    data = SyntheticLMData(cfg, global_batch=8, seq_len=32)
    tr.fit(data)
    assert len(tr.straggler_events) == 3


def test_grad_compression_int8_roundtrip():
    from repro.train.compression import dequantize_leaf, quantize_leaf
    g = jax.random.normal(jax.random.PRNGKey(0), (128, 64)) * 0.01
    q, scale = quantize_leaf(g)
    back = dequantize_leaf(q, scale, jnp.float32)
    # max quantization error is scale/2 (+ rounding slack)
    assert float(jnp.max(jnp.abs(back - g))) <= float(scale) * 0.51
    assert q.dtype == jnp.int8


def test_grad_compression_trainer_still_learns():
    cfg = _tiny_cfg()
    mesh = make_local_mesh()
    tc = TrainerConfig(steps=20, checkpoint_every=0, log_every=10,
                       grad_compression="int8")
    tr = Trainer(cfg, mesh, tc, OptConfig(lr=3e-3))
    data = SyntheticLMData(cfg, global_batch=8, seq_len=32)
    _, _, hist = tr.fit(data)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_microbatch_accumulation_matches_full_batch():
    from repro.launch.steps import make_train_step
    cfg = _tiny_cfg()
    oc = OptConfig(lr=1e-3)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params, oc)
    data = SyntheticLMData(cfg, global_batch=8, seq_len=32)
    batch = data.batch(0)
    def lr(s):
        return 1e-3

    p1, _, m1 = jax.jit(make_train_step(cfg, oc, lr, accum_steps=1))(
        params, opt, batch
    )
    p4, _, m4 = jax.jit(make_train_step(cfg, oc, lr, accum_steps=4))(
        params, opt, batch
    )
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=2e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-4)


def test_data_pipeline_deterministic_and_restart_consistent():
    cfg = _tiny_cfg()
    d1 = SyntheticLMData(cfg, global_batch=4, seq_len=16, seed=3)
    d2 = SyntheticLMData(cfg, global_batch=4, seq_len=16, seed=3)
    b1, b2 = d1.batch(5), d2.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    np.testing.assert_array_equal(
        np.asarray(b1["labels"][:, :-1]), np.asarray(b1["tokens"][:, 1:])
    )
    assert int(b1["tokens"].max()) < cfg.vocab_size


def test_server_generates():
    cfg = _tiny_cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    srv = Server(cfg, params, ServeConfig(max_len=64))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    out = srv.generate({"tokens": toks}, max_new_tokens=6)
    assert out.shape == (2, 6)
    assert (out >= 0).all() and (out < cfg.padded_vocab).all()
    # greedy decoding is deterministic
    out2 = srv.generate({"tokens": toks}, max_new_tokens=6)
    np.testing.assert_array_equal(out, out2)


def test_wsd_schedule_shape():
    lr = wsd_schedule(1.0, warmup=10, stable=20, decay=10)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(25)) == pytest.approx(1.0)
    assert float(lr(40)) < 0.05
